//! JSON snapshots of coordinator state (operator dashboards / CLI).

use crate::coordinator::service::Coordinator;
use crate::obs;
use crate::util::json::{Json, ObjBuilder};

/// Serialize service state (metrics + per-machine summary heads).
///
/// The `metrics` object keeps its original 13 keys and value shapes —
/// dashboards parsing old snapshots keep working — while two additive
/// sections carry the new observability surface: `obs` (the
/// coordinator registry's full JSON exposition, histograms included)
/// and `trace` (the most recent root span tree in the global flight
/// recorder, empty when span recording is off or nothing ran).
pub fn snapshot(c: &Coordinator) -> Json {
    let m = &c.metrics;
    let machines = c.with_machines(|ms| {
        let mut out = Vec::new();
        for (name, ms) in ms {
            let mut b = ObjBuilder::new()
                .str("name", name.as_str())
                .int("window_len", ms.window_len())
                .int("total_ingested", ms.total_ingested as usize)
                .int("since_refresh", ms.since_refresh);
            if let Some(s) = &ms.summary {
                let reps = Json::Arr(
                    s.representative_seqs
                        .iter()
                        .map(|&q| Json::Num(q as f64))
                        .collect(),
                );
                b = b
                    .val("representatives", reps)
                    .num("f_value", s.f_value as f64)
                    .num("refresh_seconds", s.refresh_seconds)
                    .int("version", s.version as usize);
            }
            out.push(b.build());
        }
        out
    });
    ObjBuilder::new()
        .str("service", c.config().name.clone())
        .int("queue_len", c.queue_len())
        .val(
            "metrics",
            ObjBuilder::new()
                .int("ingested", m.ingested.get() as usize)
                .int("malformed", m.malformed.get() as usize)
                .int("evicted", m.evicted.get() as usize)
                .int("throttle_signals", m.throttle_signals.get() as usize)
                .int("refreshes", m.refreshes.get() as usize)
                .num("refresh_seconds_total", m.refresh_seconds_total.get())
                .int("queries", m.queries.get() as usize)
                .int("fleet_queries", m.fleet_queries.get() as usize)
                .int("shard_runs", m.shard_runs.get() as usize)
                .num("shard_merge_seconds_total", m.shard_merge_seconds_total.get())
                .int("replica_count", m.replica_count.get() as usize)
                .int("shard_retries", m.shard_retries.get() as usize)
                .int("wire_bytes_total", m.wire_bytes_total.get() as usize)
                .build(),
        )
        .val("obs", obs::expo::render_json(&m.registry().snapshot()))
        .val("trace", recent_trace())
        .val("machines", Json::Arr(machines))
        .build()
}

/// The most recent root span's tree from the global flight recorder,
/// as an array of span objects (empty when nothing was recorded).
fn recent_trace() -> Json {
    let rec = &obs::global().recorder;
    let spans = rec.snapshot();
    match spans.iter().rev().find(|r| r.parent == 0) {
        Some(root) => obs::expo::trace_json(&rec.trace(root.id)),
        None => Json::Arr(vec![]),
    }
}

/// Persist a snapshot to disk (atomic: write + rename).
pub fn save(c: &Coordinator, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, snapshot(c).dump())?;
    std::fs::rename(tmp, path)
}

/// A summary head restored from a persisted snapshot — what an operator
/// dashboard can show immediately after a coordinator restart, before
/// fresh cycles arrive.
#[derive(Debug, Clone, PartialEq)]
pub struct RestoredSummary {
    pub machine: String,
    pub representative_seqs: Vec<u64>,
    pub f_value: f32,
    pub version: u64,
    pub total_ingested: u64,
}

/// Parse a persisted snapshot back into summary heads.
pub fn restore(text: &str) -> Result<Vec<RestoredSummary>, String> {
    let j = Json::parse(text).map_err(|e| e.to_string())?;
    let machines = j
        .get("machines")
        .and_then(Json::as_arr)
        .ok_or("snapshot missing machines")?;
    let mut out = Vec::with_capacity(machines.len());
    for m in machines {
        let name = m
            .get("name")
            .and_then(Json::as_str)
            .ok_or("machine missing name")?
            .to_string();
        let total = m
            .get("total_ingested")
            .and_then(Json::as_usize)
            .unwrap_or(0) as u64;
        let reps = match m.get("representatives").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|x| x.as_usize().map(|u| u as u64))
                .collect::<Option<Vec<u64>>>()
                .ok_or("bad representative seq")?,
            None => continue, // machine had no summary yet
        };
        out.push(RestoredSummary {
            machine: name,
            representative_seqs: reps,
            f_value: m
                .get("f_value")
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as f32,
            version: m.get("version").and_then(Json::as_usize).unwrap_or(0) as u64,
            total_ingested: total,
        });
    }
    Ok(out)
}

/// Load summary heads from a snapshot file.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<Vec<RestoredSummary>, String> {
    let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    restore(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ServiceConfig;
    use crate::coordinator::stream::CycleRecord;
    use crate::engine::OracleSpec;
    use crate::linalg::SharedMatrix;
    use crate::submodular::{CpuOracle, Oracle};

    #[test]
    fn snapshot_roundtrips_as_json() {
        let mut cfg = ServiceConfig::default();
        cfg.summary.k = 2;
        cfg.summary.refresh_every = 2;
        let factory = Box::new(|m: SharedMatrix, _spec: &OracleSpec| {
            Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
        });
        let c = Coordinator::new(cfg, factory);
        for s in 0..6u64 {
            c.offer(CycleRecord {
                machine: "mx".into(),
                seq: s,
                values: vec![s as f32, 1.0],
            });
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        let snap = snapshot(&c);
        let text = snap.dump();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("service").unwrap().as_str(), Some("ebc-service"));
        let machines = parsed.get("machines").unwrap().as_arr().unwrap();
        assert_eq!(machines.len(), 1);
        assert_eq!(machines[0].get("name").unwrap().as_str(), Some("mx"));
        assert!(machines[0].get("representatives").is_some());
        // frozen metrics shape: all 13 legacy keys present
        let metrics = parsed.get("metrics").unwrap();
        for key in [
            "ingested",
            "malformed",
            "evicted",
            "throttle_signals",
            "refreshes",
            "refresh_seconds_total",
            "queries",
            "fleet_queries",
            "shard_runs",
            "shard_merge_seconds_total",
            "replica_count",
            "shard_retries",
            "wire_bytes_total",
        ] {
            assert!(metrics.get(key).is_some(), "metrics key {key} missing");
        }
        assert_eq!(metrics.get("ingested").unwrap().as_usize(), Some(6));
        // additive obs section carries the registry exposition
        let obs_sec = parsed.get("obs").unwrap();
        let ing = obs_sec.get("coord_ingested_total").unwrap();
        assert_eq!(ing.get("type").unwrap().as_str(), Some("counter"));
        assert_eq!(ing.get("value").unwrap().as_usize(), Some(6));
        assert_eq!(
            obs_sec.get("coord_refresh_seconds").unwrap().get("type").unwrap().as_str(),
            Some("histogram")
        );
        assert!(parsed.get("trace").unwrap().as_arr().is_some());
    }

    fn demo_coordinator() -> Coordinator {
        let mut cfg = ServiceConfig::default();
        cfg.summary.k = 2;
        cfg.summary.refresh_every = 2;
        let factory = Box::new(|m: SharedMatrix, _spec: &OracleSpec| {
            Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
        });
        let c = Coordinator::new(cfg, factory);
        for s in 0..8u64 {
            c.offer(CycleRecord {
                machine: "mx".into(),
                seq: s,
                values: vec![s as f32, 2.0],
            });
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        c
    }

    #[test]
    fn save_load_roundtrip() {
        let c = demo_coordinator();
        let dir = std::env::temp_dir().join("ebc_snapshot_test");
        let path = dir.join("snap.json");
        save(&c, &path).unwrap();
        let restored = load(&path).unwrap();
        assert_eq!(restored.len(), 1);
        let r = &restored[0];
        assert_eq!(r.machine, "mx");
        assert_eq!(r.total_ingested, 8);
        let live = match c.query("mx") {
            crate::coordinator::RouteResult::Summary(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(r.representative_seqs, live.representative_seqs);
        assert_eq!(r.version, live.version);
        assert!((r.f_value - live.f_value).abs() < 1e-3);
    }

    #[test]
    fn restore_skips_machines_without_summary_and_rejects_garbage() {
        let text = r#"{"machines": [
            {"name": "fresh", "total_ingested": 3},
            {"name": "ready", "total_ingested": 9, "representatives": [4, 7],
             "f_value": 1.5, "version": 2}
        ]}"#;
        let rs = restore(text).unwrap();
        assert_eq!(rs.len(), 1);
        assert_eq!(rs[0].machine, "ready");
        assert_eq!(rs[0].representative_seqs, vec![4, 7]);
        assert!(restore("not json").is_err());
        assert!(restore("{}").is_err());
        assert!(restore(r#"{"machines": [{"total_ingested": 1}]}"#).is_err());
    }
}
