//! Streaming summarization coordinator — the Industry-4.0 deployment the
//! paper motivates (§6 "Summaries"): operators supervise *fleets* of
//! injection-molding machines; when they switch to one, they want a
//! short, current summary of the cycles since their last visit.
//!
//! Architecture (one process, event-driven):
//!
//! ```text
//!   sensor streams ──> backpressure queue ──> batcher ──┐
//!                                                       v
//!   operator query ──> router ──> per-machine state ──> summary
//!                                        │                 ^
//!                                        └── refresh via optimizer
//!                                            (CPU or XLA engine oracle)
//! ```
//!
//! Summaries are maintained *incrementally*: every `refresh_every` new
//! cycles the machine's sliding window is re-summarized with the
//! configured optimizer; queries are served from the cached summary in
//! O(1).
//!
//! Fleet-level queries (the reserved [`FLEET_QUERY`] name, `@fleet`)
//! pool every machine's window and answer through the sharded
//! two-stage summarizer ([`crate::shard`]), so "summarize the whole
//! fleet" scales with worker threads instead of fleet size.
//!
//! The [`Coordinator`] is a passive, shareable state core: every method
//! takes `&self` behind fine-grained locks, so it can be driven
//! single-threaded (tests, batch replay via [`Coordinator::tick`]) or
//! wrapped in the production runtime at [`crate::daemon`], which moves
//! folds, refreshes and fleet merges onto worker threads so ingest is
//! never blocked by summarization.

pub mod backpressure;
pub mod batcher;
pub mod machine;
pub mod replica;
pub mod router;
pub mod service;
pub mod snapshot;
pub mod stream;

pub use backpressure::{Admission, QueueStats};
pub use machine::{MachineState, Summary};
pub use replica::{Replica, ReplicaRegistry, ReplicaState};
pub use router::{FleetSummary, RouteResult, Router, FLEET_QUERY};
pub use service::{Coordinator, CoordinatorMetrics, OracleFactory};
pub use stream::{CycleRecord, SimulatedFleet, StreamSource};
