//! Bounded ingestion queue with watermark-based backpressure.
//!
//! Policy: below the high watermark records are accepted; between high
//! watermark and capacity the producer is advised to throttle; at
//! capacity the **oldest** record is dropped (summaries prefer fresh
//! data — a stale cycle is strictly less useful to an operator).

use std::collections::VecDeque;

/// Advice returned to the producer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Accepted,
    /// Accepted, but the queue is past the high watermark.
    AcceptedThrottle,
    /// Accepted after evicting the oldest queued record.
    AcceptedEvicted,
}

/// Point-in-time view of a [`BoundedQueue`] — what the daemon exports
/// as `ebc_daemon_ingest_*` gauges/counters so load-shedding is
/// observable instead of silent (the `evicted`/`accepted` fields used
/// to be dark: public but exported nowhere).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueStats {
    /// Records currently queued.
    pub len: usize,
    /// Capacity before the oldest record is evicted.
    pub capacity: usize,
    /// Is the queue past its high watermark (producers advised to
    /// throttle)?
    pub above_watermark: bool,
    /// Records accepted since construction (monotone).
    pub accepted: u64,
    /// Records evicted under backpressure since construction (monotone).
    pub evicted: u64,
}

/// Bounded FIFO with watermarks.
pub struct BoundedQueue<T> {
    q: VecDeque<T>,
    capacity: usize,
    high_watermark: usize,
    pub evicted: u64,
    pub accepted: u64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        assert!(capacity > 0);
        BoundedQueue {
            q: VecDeque::with_capacity(capacity),
            capacity,
            high_watermark: (capacity * 3) / 4,
            evicted: 0,
            accepted: 0,
        }
    }

    /// Snapshot the observable state (depth, watermark, counters).
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            len: self.q.len(),
            capacity: self.capacity,
            above_watermark: self.above_watermark(),
            accepted: self.accepted,
            evicted: self.evicted,
        }
    }

    /// Live-resize the queue (config reload). Shrinking below the
    /// current depth evicts the oldest records (counted as evictions);
    /// queued records otherwise survive.
    pub fn set_capacity(&mut self, capacity: usize) {
        let capacity = capacity.max(1);
        while self.q.len() > capacity {
            self.q.pop_front();
            self.evicted += 1;
        }
        self.capacity = capacity;
        self.high_watermark = (capacity * 3) / 4;
    }

    pub fn push(&mut self, item: T) -> Admission {
        self.accepted += 1;
        if self.q.len() >= self.capacity {
            self.q.pop_front();
            self.evicted += 1;
            self.q.push_back(item);
            return Admission::AcceptedEvicted;
        }
        self.q.push_back(item);
        if self.q.len() > self.high_watermark {
            Admission::AcceptedThrottle
        } else {
            Admission::Accepted
        }
    }

    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    /// Drain up to `max` items.
    pub fn drain(&mut self, max: usize) -> Vec<T> {
        let take = max.min(self.q.len());
        self.q.drain(..take).collect()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
    pub fn capacity(&self) -> usize {
        self.capacity
    }
    pub fn above_watermark(&self) -> bool {
        self.q.len() > self.high_watermark
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_below_watermark() {
        let mut q = BoundedQueue::new(8); // watermark 6
        for i in 0..6 {
            assert_eq!(q.push(i), Admission::Accepted);
        }
        assert_eq!(q.push(6), Admission::AcceptedThrottle);
        assert_eq!(q.push(7), Admission::AcceptedThrottle);
        // full: evict oldest
        assert_eq!(q.push(8), Admission::AcceptedEvicted);
        assert_eq!(q.len(), 8);
        assert_eq!(q.pop(), Some(1)); // 0 evicted
        assert_eq!(q.evicted, 1);
    }

    #[test]
    fn drain_respects_order_and_max() {
        let mut q = BoundedQueue::new(10);
        for i in 0..5 {
            q.push(i);
        }
        assert_eq!(q.drain(3), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.drain(100), vec![3, 4]);
        assert!(q.is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        BoundedQueue::<u8>::new(0);
    }

    #[test]
    fn stats_reflect_counters_and_watermark() {
        let mut q = BoundedQueue::new(4); // watermark 3
        for i in 0..5 {
            q.push(i);
        }
        let s = q.stats();
        assert_eq!(s.len, 4);
        assert_eq!(s.capacity, 4);
        assert!(s.above_watermark);
        assert_eq!(s.accepted, 5);
        assert_eq!(s.evicted, 1);
    }

    #[test]
    fn set_capacity_resizes_and_counts_evictions() {
        let mut q = BoundedQueue::new(8);
        for i in 0..6 {
            q.push(i);
        }
        q.set_capacity(3); // drops 0, 1, 2
        let s = q.stats();
        assert_eq!(s.len, 3);
        assert_eq!(s.capacity, 3);
        assert_eq!(s.evicted, 3);
        assert_eq!(q.pop(), Some(3));
        // growing preserves contents
        q.set_capacity(10);
        assert_eq!(q.stats().capacity, 10);
        assert_eq!(q.len(), 2);
        // zero clamps to one instead of panicking mid-reload
        q.set_capacity(0);
        assert_eq!(q.stats().capacity, 1);
        assert_eq!(q.len(), 1);
    }
}
