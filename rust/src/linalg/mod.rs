//! Dense linear-algebra substrate: row-major f32 matrices + the distance
//! kernels the CPU oracles and the engine's host-side paths use.
//!
//! Two CPU kernel families live here, selected by [`gemm::CpuKernel`]:
//!
//! * [`distance`] — scalar row-by-row squared-Euclidean loops. These are
//!   the paper's **ST baseline** (Fig. 2 / Table 1, single-threaded
//!   Algorithm 1), and with the set-/candidate-parallel threading in
//!   [`crate::submodular::ebc`] the paper's **MT baseline** (§4.1).
//! * [`gemm`] — the cache-blocked Gram-matrix formulation
//!   `D = vsq + vsqᵀ − 2XYᵀ` with ground-parallel threading and a
//!   software bf16 precision axis: the CPU mirror of the work-matrix
//!   kernels the paper runs on the accelerator. The `simd` backend
//!   ([`simd`]) is the same formulation with explicit AVX2/NEON
//!   micro-kernels, runtime-detected with a bit-identical scalar
//!   fallback.

pub mod distance;
pub mod gemm;
pub mod matrix;
pub mod simd;

pub use distance::{sq_euclidean, sq_euclidean_accum, sq_norms};
pub use gemm::{CpuKernel, CPU_KERNELS};
pub use matrix::Matrix;

/// Shared, immutable ground-set handle: oracles built from the same
/// dataset (merge stage, baseline run, cached CPU fallback) clone the
/// `Arc`, not the matrix — the host-side mirror of the paper's
/// "upload the ground set once" discipline.
pub type SharedMatrix = std::sync::Arc<Matrix>;
