//! Dense linear-algebra substrate: row-major f32 matrices + the distance
//! kernels the CPU baselines and the engine's host-side paths use.

pub mod distance;
pub mod matrix;

pub use distance::{sq_euclidean, sq_euclidean_accum, sq_norms};
pub use matrix::Matrix;
