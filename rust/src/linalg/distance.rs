//! Squared-Euclidean distance kernels for the CPU baselines.
//!
//! The paper (§5) uses d(x, y) = ‖x − y‖₂² throughout; the ST/MT CPU
//! implementations use the straightforward subtract-square-accumulate
//! loop in chunks of 8 so LLVM autovectorizes it (the paper's baselines
//! use OpenMP SIMD for the same inner reduction).

/// ‖x − y‖₂², autovectorized 8-lane accumulation.
#[inline]
pub fn sq_euclidean(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let b = c * 8;
        // fixed-width loop: LLVM lowers this to packed SIMD
        for lane in 0..8 {
            let d = x[b + lane] - y[b + lane];
            acc[lane] += d * d;
        }
    }
    let mut sum = acc.iter().sum::<f32>();
    for i in chunks * 8..n {
        let d = x[i] - y[i];
        sum += d * d;
    }
    sum
}

/// Same as [`sq_euclidean`] but with early-exit: stops accumulating as
/// soon as the partial sum exceeds `bound`, returning a value > bound.
/// Used by the lazy CPU evaluator where only min distances matter.
#[inline]
pub fn sq_euclidean_accum(x: &[f32], y: &[f32], bound: f32) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut sum = 0f32;
    let mut i = 0;
    let n = x.len();
    while i < n {
        let end = (i + 64).min(n);
        while i < end {
            let d = x[i] - y[i];
            sum += d * d;
            i += 1;
        }
        if sum > bound {
            return sum;
        }
    }
    sum
}

/// ‖v_i‖² for every row of a row-major (n x d) matrix.
pub fn sq_norms(data: &[f32], d: usize) -> Vec<f32> {
    assert!(d > 0 && data.len() % d == 0);
    data.chunks_exact(d)
        .map(|row| row.iter().map(|x| x * x).sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(x: &[f32], y: &[f32]) -> f32 {
        x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
    }

    #[test]
    fn matches_naive_various_lengths() {
        let mut rng = Rng::new(1);
        for n in [0, 1, 3, 7, 8, 9, 15, 16, 17, 100, 3524] {
            let x: Vec<f32> = rng.normal_vec(n);
            let y: Vec<f32> = rng.normal_vec(n);
            let a = sq_euclidean(&x, &y);
            let b = naive(&x, &y);
            assert!((a - b).abs() <= 1e-3 * (1.0 + b), "n={n}: {a} vs {b}");
        }
    }

    #[test]
    fn accum_early_exit_is_conservative() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = rng.normal_vec(512);
        let y: Vec<f32> = rng.normal_vec(512);
        let full = sq_euclidean(&x, &y);
        // generous bound: must compute the exact value
        let exact = sq_euclidean_accum(&x, &y, f32::INFINITY);
        assert!((exact - full).abs() < 1e-3 * (1.0 + full));
        // tiny bound: must return something larger than the bound
        let early = sq_euclidean_accum(&x, &y, 0.001);
        assert!(early > 0.001);
    }

    #[test]
    fn sq_norms_rows() {
        let data = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(sq_norms(&data, 2), vec![5.0, 25.0]);
        assert_eq!(sq_norms(&data, 4), vec![30.0]);
    }

    #[test]
    fn zero_distance() {
        let x = [1.5f32; 33];
        assert_eq!(sq_euclidean(&x, &x), 0.0);
    }
}
