//! Explicit-SIMD micro-kernels behind `CpuKernel::Simd` — the
//! guaranteed-vector variant of the blocked Gram-matrix path.
//!
//! [`crate::linalg::gemm`] relies on the autovectorizer turning its
//! fixed [`NR`]-wide inner loop into packed SIMD. That usually works,
//! but it is a compiler heuristic, not a contract. This module makes
//! the vector shape explicit with `std::arch` intrinsics: an AVX2
//! micro-kernel on x86-64 (one 8-lane `__m256` accumulator per X row,
//! broadcast-multiply-add over a k-major packed Y panel) and a NEON
//! mirror on aarch64 (two `float32x4_t` halves per row), picked by
//! **runtime** feature detection with a scalar fallback, plus a
//! vectorized bf16 demote for the reduced-precision input path.
//!
//! ## Bit-identity contract
//!
//! The SIMD kernels use separate multiply **then** add — never FMA —
//! so every output element accumulates its k-panel partial sums in the
//! same order, with the same per-step f32 rounding, as the blocked
//! scalar kernel (Rust forbids implicit float contraction, so the
//! autovectorized path is mul+add too). `CpuKernel::Simd` is therefore
//! **bit-identical** to `CpuKernel::Blocked` on every input, which is
//! what makes the fallback safe to take silently and lets the proptest
//! suite assert exact (to-the-bit) selection identity instead of a
//! tolerance band. The win is not different math — it is the guarantee
//! of vector execution plus the k-major Y panel packing, which turns
//! the blocked kernel's strided per-k column gathers into contiguous
//! 8-lane loads.
//!
//! The forced-fallback hook ([`force_scalar`]) exists so tests can
//! prove the degradation path: with detection overridden, `Simd`
//! routes to the blocked scalar loop and must produce the same bits.

use super::gemm::{bf16_round, gemm_nt_blocked, micro_edge, KC, MR, NR};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// What the runtime dispatcher found at first use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// No usable vector extension (or detection overridden): the
    /// `simd` kernel delegates to the blocked scalar loop.
    Scalar,
    /// 8-lane AVX2 micro-kernels (x86-64 / x86).
    Avx2,
    /// 2×4-lane NEON micro-kernels (aarch64).
    Neon,
}

impl SimdLevel {
    /// Stable lowercase label (bench JSON, CLI output).
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Override runtime detection and force the `simd` kernel down its
/// scalar fallback (the degradation path a non-AVX2/NEON host takes).
/// Returns the previous setting so tests can restore it. Safe at any
/// time: the fallback is bit-identical, so in-flight work is unaffected.
pub fn force_scalar(on: bool) -> bool {
    FORCE_SCALAR.swap(on, Ordering::SeqCst)
}

/// The vector extension this host actually has (cached at first call,
/// ignores [`force_scalar`]).
pub fn detected() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        {
            if is_x86_feature_detected!("avx2") {
                return SimdLevel::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return SimdLevel::Neon;
            }
        }
        SimdLevel::Scalar
    })
}

/// The level the dispatcher will actually use right now: [`detected`]
/// unless [`force_scalar`] is in effect.
pub fn level() -> SimdLevel {
    if FORCE_SCALAR.load(Ordering::SeqCst) {
        SimdLevel::Scalar
    } else {
        detected()
    }
}

/// `out += X·Yᵀ` through the best available vector micro-kernel —
/// the `CpuKernel::Simd` body behind [`crate::linalg::gemm::gemm_nt_with`]
/// (which owns the shape asserts and the latency histogram).
pub(crate) fn gemm_nt_dispatch(x: &[f32], y: &[f32], d: usize, m: usize, c: usize, out: &mut [f32]) {
    match level() {
        SimdLevel::Scalar => gemm_nt_blocked(x, y, d, m, c, out),
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        // SAFETY: level() == Avx2 only after is_x86_feature_detected!("avx2").
        SimdLevel::Avx2 => unsafe { x86::gemm_nt_avx2(x, y, d, m, c, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: level() == Neon only after is_aarch64_feature_detected!("neon").
        SimdLevel::Neon => unsafe { arm::gemm_nt_neon(x, y, d, m, c, out) },
        // levels whose arch-specific arm is compiled out (Neon on x86,
        // Avx2 on aarch64) can never be produced by level() here, but
        // the variants still exist — fall back to the blocked loop
        _ => gemm_nt_blocked(x, y, d, m, c, out),
    }
}

/// Vectorized [`bf16_round`] over a whole slice — bit-identical to the
/// scalar demote on every input, NaNs (sign and payload) included.
pub(crate) fn demote_bf16_dispatch(data: &[f32]) -> Vec<f32> {
    match level() {
        SimdLevel::Scalar => data.iter().map(|&v| bf16_round(v)).collect(),
        #[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
        // SAFETY: level() == Avx2 only after is_x86_feature_detected!("avx2").
        SimdLevel::Avx2 => unsafe { x86::demote_bf16_avx2(data) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: level() == Neon only after is_aarch64_feature_detected!("neon").
        SimdLevel::Neon => unsafe { arm::demote_bf16_neon(data) },
        _ => data.iter().map(|&v| bf16_round(v)).collect(),
    }
}

#[cfg(any(target_arch = "x86_64", target_arch = "x86"))]
mod x86 {
    use super::*;
    #[cfg(target_arch = "x86")]
    use std::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// AVX2 `out += X·Yᵀ`: same k0 → tile → element accumulation order
    /// as the blocked scalar kernel, so results are bit-identical.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_nt_avx2(
        x: &[f32],
        y: &[f32],
        d: usize,
        m: usize,
        c: usize,
        out: &mut [f32],
    ) {
        // k-major packed panel for NR y-rows: yp[kk*NR + jj] holds
        // y[(j0+jj)*d + k0+kk], so the micro-kernel loads one
        // contiguous 8-lane vector per k step instead of gathering
        // NR strided columns. KC·NR f32 = 8 KB, L1-resident.
        let mut yp = [0f32; KC * NR];
        let mut k0 = 0;
        while k0 < d {
            let kend = (k0 + KC).min(d);
            let mut j0 = 0;
            while j0 < c {
                let jend = (j0 + NR).min(c);
                if jend - j0 == NR {
                    for jj in 0..NR {
                        let row = &y[(j0 + jj) * d + k0..(j0 + jj) * d + kend];
                        for (kk, &v) in row.iter().enumerate() {
                            yp[kk * NR + jj] = v;
                        }
                    }
                    let mut i0 = 0;
                    while i0 + MR <= m {
                        micro_avx2(x, &yp, d, c, i0, j0, k0, kend - k0, out);
                        i0 += MR;
                    }
                    if i0 < m {
                        micro_edge(x, y, d, c, i0, m, j0, jend, k0, kend, out);
                    }
                } else {
                    micro_edge(x, y, d, c, 0, m, j0, jend, k0, kend, out);
                }
                j0 = jend;
            }
            k0 = kend;
        }
    }

    /// Full MR×NR tile: one `__m256` accumulator per X row, broadcast ·
    /// panel-load, separate mul + add (never FMA — see the module's
    /// bit-identity contract).
    ///
    /// # Safety
    /// AVX2 must be available; `x` must cover rows `i0..i0+MR` at
    /// stride `d` from column `k0` for `kc` columns, `out` rows
    /// `i0..i0+MR` at stride `c` from column `j0` for [`NR`] columns.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn micro_avx2(
        x: &[f32],
        yp: &[f32; KC * NR],
        d: usize,
        c: usize,
        i0: usize,
        j0: usize,
        k0: usize,
        kc: usize,
        out: &mut [f32],
    ) {
        let mut acc = [_mm256_setzero_ps(); MR];
        for kk in 0..kc {
            let b = _mm256_loadu_ps(yp.as_ptr().add(kk * NR));
            for (ii, a) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*x.get_unchecked((i0 + ii) * d + k0 + kk));
                *a = _mm256_add_ps(*a, _mm256_mul_ps(av, b));
            }
        }
        for (ii, &v) in acc.iter().enumerate() {
            let p = out.as_mut_ptr().add((i0 + ii) * c + j0);
            _mm256_storeu_ps(p, _mm256_add_ps(_mm256_loadu_ps(p), v));
        }
    }

    /// 8-lane [`bf16_round`]: the same integer round-to-nearest-even
    /// (`bits + 0x7FFF + lsb`, wrapping) on all lanes, with a compare
    /// blend to pass NaNs through untouched exactly like the scalar.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support at runtime.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn demote_bf16_avx2(data: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; data.len()];
        let chunks = data.len() / 8;
        let bias = _mm256_set1_epi32(0x7FFF);
        let one = _mm256_set1_epi32(1);
        let mask = _mm256_set1_epi32(0xFFFF_0000u32 as i32);
        for i in 0..chunks {
            let v = _mm256_loadu_ps(data.as_ptr().add(i * 8));
            let bits = _mm256_castps_si256(v);
            let lsb = _mm256_and_si256(_mm256_srli_epi32(bits, 16), one);
            let rounded = _mm256_add_epi32(bits, _mm256_add_epi32(bias, lsb));
            let masked = _mm256_castsi256_ps(_mm256_and_si256(rounded, mask));
            let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(v, v);
            let r = _mm256_blendv_ps(masked, v, nan);
            _mm256_storeu_ps(out.as_mut_ptr().add(i * 8), r);
        }
        for i in chunks * 8..data.len() {
            out[i] = bf16_round(data[i]);
        }
        out
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::*;
    use std::arch::aarch64::*;

    /// NEON `out += X·Yᵀ` — the AVX2 kernel's structure with each
    /// 8-lane vector split into two `float32x4_t` halves.
    ///
    /// # Safety
    /// Caller must have verified NEON support at runtime.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_nt_neon(
        x: &[f32],
        y: &[f32],
        d: usize,
        m: usize,
        c: usize,
        out: &mut [f32],
    ) {
        let mut yp = [0f32; KC * NR];
        let mut k0 = 0;
        while k0 < d {
            let kend = (k0 + KC).min(d);
            let mut j0 = 0;
            while j0 < c {
                let jend = (j0 + NR).min(c);
                if jend - j0 == NR {
                    for jj in 0..NR {
                        let row = &y[(j0 + jj) * d + k0..(j0 + jj) * d + kend];
                        for (kk, &v) in row.iter().enumerate() {
                            yp[kk * NR + jj] = v;
                        }
                    }
                    let mut i0 = 0;
                    while i0 + MR <= m {
                        micro_neon(x, &yp, d, c, i0, j0, k0, kend - k0, out);
                        i0 += MR;
                    }
                    if i0 < m {
                        micro_edge(x, y, d, c, i0, m, j0, jend, k0, kend, out);
                    }
                } else {
                    micro_edge(x, y, d, c, 0, m, j0, jend, k0, kend, out);
                }
                j0 = jend;
            }
            k0 = kend;
        }
    }

    /// Full MR×NR tile on two 4-lane halves per row; `vmulq` + `vaddq`
    /// (never `vmlaq`/`vfmaq`, which contract — see the bit-identity
    /// contract).
    ///
    /// # Safety
    /// NEON must be available; slice bounds as in the AVX2 micro-kernel.
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn micro_neon(
        x: &[f32],
        yp: &[f32; KC * NR],
        d: usize,
        c: usize,
        i0: usize,
        j0: usize,
        k0: usize,
        kc: usize,
        out: &mut [f32],
    ) {
        let zero = vdupq_n_f32(0.0);
        let mut lo = [zero; MR];
        let mut hi = [zero; MR];
        for kk in 0..kc {
            let b0 = vld1q_f32(yp.as_ptr().add(kk * NR));
            let b1 = vld1q_f32(yp.as_ptr().add(kk * NR + 4));
            for ii in 0..MR {
                let av = vdupq_n_f32(*x.get_unchecked((i0 + ii) * d + k0 + kk));
                lo[ii] = vaddq_f32(lo[ii], vmulq_f32(av, b0));
                hi[ii] = vaddq_f32(hi[ii], vmulq_f32(av, b1));
            }
        }
        for ii in 0..MR {
            let p = out.as_mut_ptr().add((i0 + ii) * c + j0);
            vst1q_f32(p, vaddq_f32(vld1q_f32(p), lo[ii]));
            vst1q_f32(p.add(4), vaddq_f32(vld1q_f32(p.add(4)), hi[ii]));
        }
    }

    /// 4-lane [`bf16_round`] with a self-equality select for NaN
    /// passthrough.
    ///
    /// # Safety
    /// Caller must have verified NEON support at runtime.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn demote_bf16_neon(data: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; data.len()];
        let chunks = data.len() / 4;
        let bias = vdupq_n_u32(0x7FFF);
        let one = vdupq_n_u32(1);
        let mask = vdupq_n_u32(0xFFFF_0000);
        for i in 0..chunks {
            let v = vld1q_f32(data.as_ptr().add(i * 4));
            let bits = vreinterpretq_u32_f32(v);
            let lsb = vandq_u32(vshrq_n_u32(bits, 16), one);
            let rounded = vaddq_u32(bits, vaddq_u32(bias, lsb));
            let masked = vandq_u32(rounded, mask);
            // vceqq is false exactly on NaN lanes: select the original
            // bits there, the rounded bits everywhere else
            let ordered = vceqq_f32(v, v);
            let r = vbslq_u32(ordered, masked, bits);
            vst1q_f32(out.as_mut_ptr().add(i * 4), vreinterpretq_f32_u32(r));
        }
        for i in chunks * 4..data.len() {
            out[i] = bf16_round(data[i]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{demote_bf16, gemm_nt, gemm_nt_with, CpuKernel};
    use crate::util::rng::Rng;

    #[test]
    fn detected_level_is_stable_and_named() {
        let l = detected();
        assert_eq!(detected(), l);
        assert!(["scalar", "avx2", "neon"].contains(&l.name()));
    }

    #[test]
    fn simd_gemm_bit_identical_to_blocked_awkward_shapes() {
        let mut rng = Rng::new(11);
        // straddle MR/NR/KC borders, incl. single row/col and empty
        for &(m, c, d) in &[
            (0usize, 5usize, 3usize),
            (5, 0, 3),
            (1, 1, 1),
            (1, 9, 7),
            (7, 9, 5),
            (8, 8, 8),
            (9, 17, 31),
            (16, 16, 257),
            (13, 5, 300),
            (24, 33, 260),
        ] {
            let x: Vec<f32> = rng.normal_vec(m * d);
            let y: Vec<f32> = rng.normal_vec(c * d);
            let mut blocked = vec![0f32; m * c];
            gemm_nt(&x, &y, d, m, c, &mut blocked);
            let mut simd = vec![0f32; m * c];
            gemm_nt_with(CpuKernel::Simd, &x, &y, d, m, c, &mut simd);
            for (i, (a, b)) in simd.iter().zip(&blocked).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "m={m} c={c} d={d} elem {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn simd_gemm_accumulates_into_out() {
        let x = [1.0f32, 2.0];
        let y = [3.0f32, 4.0];
        let mut out = [10.0f32];
        gemm_nt_with(CpuKernel::Simd, &x, &y, 2, 1, 1, &mut out);
        assert_eq!(out[0], 21.0);
    }

    // tests that flip the process-global FORCE_SCALAR serialize here;
    // everything else is flag-agnostic (both paths are bit-identical)
    static FORCE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn forced_fallback_is_bit_identical() {
        let _g = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let mut rng = Rng::new(12);
        let (m, c, d) = (19, 23, 37);
        let x: Vec<f32> = rng.normal_vec(m * d);
        let y: Vec<f32> = rng.normal_vec(c * d);
        let mut native = vec![0f32; m * c];
        gemm_nt_with(CpuKernel::Simd, &x, &y, d, m, c, &mut native);
        let prev = force_scalar(true);
        assert_eq!(level(), SimdLevel::Scalar);
        let mut forced = vec![0f32; m * c];
        gemm_nt_with(CpuKernel::Simd, &x, &y, d, m, c, &mut forced);
        let demoted = demote_bf16_dispatch(&x);
        force_scalar(prev);
        for (a, b) in native.iter().zip(&forced) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in demoted.iter().zip(&demote_bf16(&x)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn vector_demote_matches_scalar_bitwise() {
        let mut rng = Rng::new(13);
        // oddball lengths force the scalar tail; specials cover the
        // NaN blend, infinities, signed zero, subnormals and the
        // round-up-to-inf edge of the bias add
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 100, 1001] {
            let mut data: Vec<f32> = rng.normal_vec(n).iter().map(|v| v * 1e3).collect();
            for (i, s) in [
                f32::NAN,
                -f32::NAN,
                f32::INFINITY,
                f32::NEG_INFINITY,
                0.0,
                -0.0,
                f32::MIN_POSITIVE,
                f32::MAX,
                f32::MIN,
                1.0e-40,
            ]
            .iter()
            .enumerate()
            {
                if i < data.len() {
                    data[i] = *s;
                }
            }
            let fast = demote_bf16_dispatch(&data);
            for (i, (a, &v)) in fast.iter().zip(&data).enumerate() {
                let want = bf16_round(v);
                assert_eq!(
                    a.to_bits(),
                    want.to_bits(),
                    "n={n} elem {i}: {a} vs {want} (input {v})"
                );
            }
        }
    }

    #[test]
    fn force_scalar_roundtrips() {
        let _g = FORCE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let prev = force_scalar(true);
        assert!(force_scalar(prev));
        assert_eq!(FORCE_SCALAR.load(Ordering::SeqCst), prev);
    }
}
