//! Tiled Gram-matrix (GEMM) kernel backend for the CPU oracle hot path.
//!
//! The paper's speedup story (§4, Table 1) comes from recasting EBC
//! evaluation as dense work-matrix algebra: instead of per-pair
//! subtract-square-accumulate loops, every distance block is computed as
//!
//! ```text
//! D = vsq_rows · 1ᵀ + 1 · vsq_colsᵀ − 2 · X · Yᵀ
//! ```
//!
//! so the dominant cost is one dense matmul. This module is the CPU
//! mirror of that formulation: a cache-blocked `X·Yᵀ` ([`gemm_nt`]) with
//! an [`MR`]×[`NR`] register micro-kernel and a [`KC`]-deep L1 tile over
//! the feature dimension, the distance expansion on top of it
//! ([`sq_dist_block`]), and a reduced-precision path ([`bf16_round`] /
//! [`demote_bf16`]: inputs rounded to bf16-representable values,
//! accumulation kept in f32 — the software analogue of the paper's FP16
//! axis that gave up to 452x).
//!
//! The scalar row-by-row kernels in [`super::distance`] remain the
//! paper's ST/MT baselines; [`CpuKernel`] is the backend seam the rest
//! of the stack (config, CLI, shard workers, coordinator) selects with.
//! [`CpuKernel::Simd`] swaps this module's autovectorized micro-kernel
//! for the explicit `std::arch` ones in [`super::simd`] — same math,
//! same bits, guaranteed vector execution.

use crate::obs;
use anyhow::{bail, Result};
use std::sync::OnceLock;

fn gemm_hist() -> &'static obs::Histogram {
    static H: OnceLock<obs::Histogram> = OnceLock::new();
    H.get_or_init(|| {
        obs::histogram(obs::GEMM_SECONDS, "blocked Gram-matrix (gemm_nt) call latency (seconds)")
    })
}

/// CPU oracle kernel backend: the paper's scalar ST/MT baseline loops,
/// or the blocked Gram-matrix formulation of this module — with or
/// without explicit vector micro-kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CpuKernel {
    /// Row-by-row `sq_euclidean` loops ([`super::distance`]) — the
    /// paper's ST baseline (candidate-/set-parallel when threaded).
    Scalar,
    /// Cache-blocked `D = vsq + vsqᵀ − 2XYᵀ` with ground-parallel
    /// threading — the work-matrix formulation on the CPU, relying on
    /// the autovectorizer for SIMD.
    Blocked,
    /// The blocked formulation with explicit `std::arch` micro-kernels
    /// ([`super::simd`]): AVX2/NEON picked at runtime, scalar fallback
    /// elsewhere. Bit-identical to [`CpuKernel::Blocked`] on every
    /// input (same accumulation order, mul+add, no FMA).
    Simd,
}

/// Kernel names accepted by [`CpuKernel::parse`] (and therefore by
/// `engine.cpu_kernel` in the config schema and the CLI flags).
pub const CPU_KERNELS: &[&str] = &["scalar", "blocked", "simd"];

impl CpuKernel {
    pub fn parse(s: &str) -> Result<CpuKernel> {
        Ok(match s {
            "scalar" => CpuKernel::Scalar,
            "blocked" | "gemm" => CpuKernel::Blocked,
            "simd" => CpuKernel::Simd,
            other => bail!("unknown cpu kernel '{other}' (expected one of {CPU_KERNELS:?})"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CpuKernel::Scalar => "scalar",
            CpuKernel::Blocked => "blocked",
            CpuKernel::Simd => "simd",
        }
    }

    /// Whether this backend evaluates through the Gram-matrix
    /// formulation (`blocked` and `simd`, which share one numerical
    /// contract) rather than the scalar row-by-row baseline. The seam
    /// the oracle uses to pick its evaluation strategy without
    /// enumerating gemm-family variants at every site.
    pub fn uses_gemm(&self) -> bool {
        !matches!(self, CpuKernel::Scalar)
    }
}

/// Micro-kernel register-tile height (rows of X per inner tile).
pub const MR: usize = 8;
/// Micro-kernel register-tile width (rows of Y per inner tile).
pub const NR: usize = 8;
/// L1 tile depth over the feature dimension: KC f32 ≈ 1 KB per row, so
/// one MR-row X panel + one NR-row Y panel stay L1-resident (~16 KB).
pub const KC: usize = 256;

/// `out` (m×c, row-major) ← `out + X·Yᵀ` with X (m×d) and Y (c×d) both
/// row-major — the "NT" Gram product where every entry is a row-row dot.
/// `out` must be zeroed (or hold a partial product) on entry; f32
/// accumulation throughout, k blocked by [`KC`], [`MR`]×[`NR`] register
/// tiles with a scalar edge path for ragged borders.
///
/// This is the autovectorized blocked path
/// (`gemm_nt_with(CpuKernel::Blocked, ...)`); gemm-family callers that
/// carry a kernel choice go through [`gemm_nt_with`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt(x: &[f32], y: &[f32], d: usize, m: usize, c: usize, out: &mut [f32]) {
    gemm_nt_with(CpuKernel::Blocked, x, y, d, m, c, out)
}

/// [`gemm_nt`] through a chosen backend: [`CpuKernel::Simd`] routes to
/// the explicit vector micro-kernels in [`super::simd`] (bit-identical
/// to the blocked loop — see that module's contract), everything else
/// runs the blocked loop. Both share the shape asserts and the
/// `ebc_gemm_seconds` histogram.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt_with(
    kernel: CpuKernel,
    x: &[f32],
    y: &[f32],
    d: usize,
    m: usize,
    c: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), m * d, "X shape mismatch");
    assert_eq!(y.len(), c * d, "Y shape mismatch");
    assert_eq!(out.len(), m * c, "out shape mismatch");
    gemm_hist().time(|| match kernel {
        CpuKernel::Simd => super::simd::gemm_nt_dispatch(x, y, d, m, c, out),
        _ => gemm_nt_blocked(x, y, d, m, c, out),
    })
}

/// The blocked loop body (no asserts, no histogram): also the scalar
/// fallback target for [`super::simd`]'s runtime dispatch.
pub(crate) fn gemm_nt_blocked(x: &[f32], y: &[f32], d: usize, m: usize, c: usize, out: &mut [f32]) {
    let mut k0 = 0;
    while k0 < d {
        let kend = (k0 + KC).min(d);
        let mut i0 = 0;
        while i0 < m {
            let iend = (i0 + MR).min(m);
            let mut j0 = 0;
            while j0 < c {
                let jend = (j0 + NR).min(c);
                if iend - i0 == MR && jend - j0 == NR {
                    micro_full(x, y, d, c, i0, j0, k0, kend, out);
                } else {
                    micro_edge(x, y, d, c, i0, iend, j0, jend, k0, kend, out);
                }
                j0 = jend;
            }
            i0 = iend;
        }
        k0 = kend;
    }
}

/// Full MR×NR register tile: rank-1 updates over the k panel; the fixed
/// NR-wide inner loop lowers to packed SIMD.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_full(
    x: &[f32],
    y: &[f32],
    d: usize,
    c: usize,
    i0: usize,
    j0: usize,
    k0: usize,
    kend: usize,
    out: &mut [f32],
) {
    let mut acc = [[0f32; NR]; MR];
    for k in k0..kend {
        let mut yv = [0f32; NR];
        for (jj, v) in yv.iter_mut().enumerate() {
            *v = y[(j0 + jj) * d + k];
        }
        for (ii, row) in acc.iter_mut().enumerate() {
            let a = x[(i0 + ii) * d + k];
            for (r, &b) in row.iter_mut().zip(&yv) {
                *r += a * b;
            }
        }
    }
    for (ii, row) in acc.iter().enumerate() {
        let base = (i0 + ii) * c + j0;
        for (jj, &v) in row.iter().enumerate() {
            out[base + jj] += v;
        }
    }
}

/// Ragged border tile: plain dot products over the k panel. Shared
/// with [`super::simd`], whose vector kernels take the same edge path
/// (part of the bit-identity contract).
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn micro_edge(
    x: &[f32],
    y: &[f32],
    d: usize,
    c: usize,
    i0: usize,
    iend: usize,
    j0: usize,
    jend: usize,
    k0: usize,
    kend: usize,
    out: &mut [f32],
) {
    for i in i0..iend {
        for j in j0..jend {
            let mut s = 0f32;
            for k in k0..kend {
                s += x[i * d + k] * y[j * d + k];
            }
            out[i * c + j] += s;
        }
    }
}

/// `out` (m×c) ← max(0, vsq_x[i] + vsq_y[j] − 2·⟨x_i, y_j⟩): the paper's
/// work-matrix distance expansion over one ground-row block. Clamped at
/// zero — exact squared distances are non-negative, but the expanded
/// form can go slightly negative under cancellation (e.g. i == j).
#[allow(clippy::too_many_arguments)]
pub fn sq_dist_block(
    x: &[f32],
    vsq_x: &[f32],
    y: &[f32],
    vsq_y: &[f32],
    d: usize,
    m: usize,
    c: usize,
    out: &mut [f32],
) {
    sq_dist_block_with(CpuKernel::Blocked, x, vsq_x, y, vsq_y, d, m, c, out)
}

/// [`sq_dist_block`] through a chosen gemm-family backend (the
/// expansion on top of [`gemm_nt_with`]).
#[allow(clippy::too_many_arguments)]
pub fn sq_dist_block_with(
    kernel: CpuKernel,
    x: &[f32],
    vsq_x: &[f32],
    y: &[f32],
    vsq_y: &[f32],
    d: usize,
    m: usize,
    c: usize,
    out: &mut [f32],
) {
    assert_eq!(vsq_x.len(), m, "vsq_x length mismatch");
    assert_eq!(vsq_y.len(), c, "vsq_y length mismatch");
    out.fill(0.0);
    gemm_nt_with(kernel, x, y, d, m, c, out);
    for i in 0..m {
        let vx = vsq_x[i];
        let row = &mut out[i * c..(i + 1) * c];
        for (o, &vy) in row.iter_mut().zip(vsq_y) {
            let v = vx + vy - 2.0 * *o;
            *o = if v > 0.0 { v } else { 0.0 };
        }
    }
}

/// Round an f32 to the nearest bf16-representable value (ties to even),
/// returned as f32 — the input side of the reduced-precision path: the
/// paper runs FP16 work matrices on the accelerator; on the CPU we
/// demote inputs and keep f32 accumulation, so the error model matches
/// the input-quantization component of that axis.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let bits = x.to_bits();
    let rounded = bits.wrapping_add(0x7FFF + ((bits >> 16) & 1));
    f32::from_bits(rounded & 0xFFFF_0000)
}

/// Demote every element to its nearest bf16-representable value.
pub fn demote_bf16(data: &[f32]) -> Vec<f32> {
    data.iter().map(|&v| bf16_round(v)).collect()
}

/// [`demote_bf16`] through a chosen backend: [`CpuKernel::Simd`] runs
/// the vectorized demote in [`super::simd`] (bit-identical, NaNs
/// included), everything else the scalar map.
pub fn demote_bf16_with(kernel: CpuKernel, data: &[f32]) -> Vec<f32> {
    match kernel {
        CpuKernel::Simd => super::simd::demote_bf16_dispatch(data),
        _ => demote_bf16(data),
    }
}

/// Ground-row tile height for an (h×c) distance block: sized so the
/// block stays ≈128 KB (L2-resident), floored at [`MR`] and kept a
/// multiple of it so full micro-tiles dominate.
pub fn tile_rows(c: usize) -> usize {
    let target = (128 * 1024) / (4 * c.max(1));
    (target.clamp(MR, 512) / MR) * MR
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{sq_euclidean, sq_norms};
    use crate::util::rng::Rng;

    fn naive_nt(x: &[f32], y: &[f32], d: usize, m: usize, c: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * c];
        for i in 0..m {
            for j in 0..c {
                out[i * c + j] = (0..d).map(|k| x[i * d + k] * y[j * d + k]).sum();
            }
        }
        out
    }

    #[test]
    fn gemm_matches_naive_awkward_shapes() {
        let mut rng = Rng::new(1);
        // shapes straddling the MR/NR/KC tile borders
        for &(m, c, d) in &[
            (0usize, 5usize, 3usize),
            (5, 0, 3),
            (1, 1, 1),
            (7, 9, 5),
            (8, 8, 8),
            (9, 17, 31),
            (16, 16, 257),
            (13, 5, 300),
        ] {
            let x: Vec<f32> = rng.normal_vec(m * d);
            let y: Vec<f32> = rng.normal_vec(c * d);
            let mut out = vec![0f32; m * c];
            gemm_nt(&x, &y, d, m, c, &mut out);
            let want = naive_nt(&x, &y, d, m, c);
            for (a, b) in out.iter().zip(&want) {
                assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "m={m} c={c} d={d}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn gemm_accumulates_into_out() {
        let x = [1.0f32, 2.0];
        let y = [3.0f32, 4.0];
        let mut out = [10.0f32];
        gemm_nt(&x, &y, 2, 1, 1, &mut out);
        assert_eq!(out[0], 21.0); // 10 + (3 + 8)
    }

    #[test]
    fn sq_dist_block_matches_scalar_kernel() {
        let mut rng = Rng::new(2);
        for &(m, c, d) in &[(6usize, 4usize, 3usize), (17, 9, 33), (8, 8, 8)] {
            let x: Vec<f32> = rng.normal_vec(m * d);
            let y: Vec<f32> = rng.normal_vec(c * d);
            let vsq_x = sq_norms(&x, d);
            let vsq_y = sq_norms(&y, d);
            let mut out = vec![0f32; m * c];
            sq_dist_block(&x, &vsq_x, &y, &vsq_y, d, m, c, &mut out);
            for i in 0..m {
                for j in 0..c {
                    let want = sq_euclidean(&x[i * d..(i + 1) * d], &y[j * d..(j + 1) * d]);
                    let got = out[i * c + j];
                    assert!(
                        (got - want).abs() <= 1e-3 * (1.0 + want),
                        "({i},{j}): {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn sq_dist_block_self_distance_clamped_nonnegative() {
        let mut rng = Rng::new(3);
        let d = 19;
        let x: Vec<f32> = rng.normal_vec(5 * d);
        let vsq = sq_norms(&x, d);
        let mut out = vec![0f32; 5 * 5];
        sq_dist_block(&x, &vsq, &x, &vsq, d, 5, 5, &mut out);
        for (i, row) in out.chunks(5).enumerate() {
            assert!(row.iter().all(|&v| v >= 0.0), "row {i}: {row:?}");
            assert!(row[i] <= 1e-3 * (1.0 + vsq[i]), "self-dist {}", row[i]);
        }
    }

    #[test]
    fn bf16_round_properties() {
        // idempotent, exact on bf16-representable values, signs preserved
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 3.0, 256.0, -0.375, f32::INFINITY] {
            assert_eq!(bf16_round(v), v, "representable {v}");
        }
        let mut rng = Rng::new(4);
        for _ in 0..1000 {
            let v = rng.normal() * 100.0;
            let r = bf16_round(v);
            assert_eq!(bf16_round(r), r, "not idempotent at {v}");
            // bf16 keeps 8 significand bits: relative error < 2^-8
            assert!(
                (r - v).abs() <= v.abs() / 256.0 + f32::MIN_POSITIVE,
                "{v} -> {r}"
            );
        }
        assert!(bf16_round(f32::NAN).is_nan());
    }

    #[test]
    fn demote_is_elementwise() {
        let data = [1.0f32, 3.14159, -2.71828];
        let lp = demote_bf16(&data);
        assert_eq!(lp.len(), 3);
        for (a, b) in data.iter().zip(&lp) {
            assert_eq!(bf16_round(*a), *b);
        }
    }

    #[test]
    fn tile_rows_bounds() {
        assert_eq!(tile_rows(0) % MR, 0);
        for c in [1usize, 7, 64, 1024, 1 << 20] {
            let t = tile_rows(c);
            assert!(t >= MR && t <= 512 && t % MR == 0, "c={c}: {t}");
        }
        // large candidate blocks shrink the tile
        assert!(tile_rows(1 << 20) == MR);
        assert!(tile_rows(1) > tile_rows(1024));
    }

    #[test]
    fn cpu_kernel_parse_roundtrip() {
        for name in CPU_KERNELS {
            assert_eq!(CpuKernel::parse(name).unwrap().name(), *name);
        }
        assert_eq!(CpuKernel::parse("gemm").unwrap(), CpuKernel::Blocked);
        assert_eq!(CpuKernel::parse("simd").unwrap(), CpuKernel::Simd);
        assert!(CpuKernel::parse("psychic").is_err());
    }

    #[test]
    fn gemm_family_membership() {
        assert!(!CpuKernel::Scalar.uses_gemm());
        assert!(CpuKernel::Blocked.uses_gemm());
        assert!(CpuKernel::Simd.uses_gemm());
    }

    #[test]
    fn demote_with_matches_scalar_for_every_kernel() {
        let data = [1.0f32, 3.14159, -2.71828, f32::NAN, f32::INFINITY, -0.0];
        let want = demote_bf16(&data);
        for k in [CpuKernel::Scalar, CpuKernel::Blocked, CpuKernel::Simd] {
            let got = demote_bf16_with(k, &data);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "kernel {:?}", k);
            }
        }
    }
}
