//! Row-major dense f32 matrix. Deliberately small: the heavy lifting
//! happens in the XLA engine; this type carries datasets, candidate
//! batches and evaluation sets between modules.

use crate::util::rng::Rng;

/// Row-major dense matrix of f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build from row slices.
    pub fn from_rows(rows: &[&[f32]]) -> Matrix {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Matrix { rows: rows.len(), cols, data }
    }

    /// i.i.d. standard-normal entries (the paper's synthetic benchmark data).
    pub fn random_normal(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Gather rows by index into a new matrix.
    pub fn gather(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Copy this matrix into a zero-padded (rows_pad x cols_pad) buffer.
    pub fn padded(&self, rows_pad: usize, cols_pad: usize) -> Matrix {
        assert!(rows_pad >= self.rows && cols_pad >= self.cols);
        let mut out = Matrix::zeros(rows_pad, cols_pad);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
        }
        out
    }

    /// Vertical concat.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(0), &[1., 2., 3.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!((m.rows(), m.cols()), (2, 3));
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn bad_shape_panics() {
        Matrix::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn gather_rows() {
        let m = Matrix::from_rows(&[&[1., 2.], &[3., 4.], &[5., 6.]]);
        let g = m.gather(&[2, 0]);
        assert_eq!(g.row(0), &[5., 6.]);
        assert_eq!(g.row(1), &[1., 2.]);
    }

    #[test]
    fn padded_zero_fills() {
        let m = Matrix::from_rows(&[&[1., 2.]]);
        let p = m.padded(2, 4);
        assert_eq!(p.row(0), &[1., 2., 0., 0.]);
        assert_eq!(p.row(1), &[0., 0., 0., 0.]);
    }

    #[test]
    fn vstack() {
        let a = Matrix::from_rows(&[&[1., 2.]]);
        let b = Matrix::from_rows(&[&[3., 4.], &[5., 6.]]);
        let c = a.vstack(&b);
        assert_eq!(c.rows(), 3);
        assert_eq!(c.row(2), &[5., 6.]);
    }

    #[test]
    fn random_reproducible() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        assert_eq!(
            Matrix::random_normal(4, 3, &mut r1),
            Matrix::random_normal(4, 3, &mut r2)
        );
    }
}
