//! Golden conformance suite for the shard wire format
//! (`ebc::shard::wire`).
//!
//! The hex frames below are **frozen**: `encode(struct)` must reproduce
//! them byte for byte and `decode(golden)` must reproduce the structs,
//! so any layout change breaks this suite and forces a conscious
//! `WIRE_VERSION` bump (plus regenerated goldens). The corruption half
//! proves decoding is total: truncated, bit-flipped, resized and
//! unknown-version frames yield typed [`WireError`]s, never panics.
//!
//! Provenance: every hex frame was minted by the independent Python
//! mirror of the encoders, now committed as
//! `python/tests/test_wire_goldens.py`. That mirror re-derives all nine
//! frames from the documented layout (stdlib struct + zlib only) and
//! they match the Rust encoders byte for byte — neither side has been
//! found wrong to date. Until a cargo run confirms the Rust half in CI,
//! the mirror is the executable cross-check; run it with
//! `python3 python/tests/test_wire_goldens.py`.

use ebc::engine::{KernelImpl, Precision};
use ebc::imm::{Part, ProcessState};
use ebc::linalg::{CpuKernel, Matrix};
use ebc::shard::wire::{
    crc32, decode_goodbye, decode_heartbeat, decode_hello, decode_job, decode_request,
    decode_result, encode_goodbye, encode_heartbeat, encode_hello, encode_job, encode_request,
    encode_result, frame_kind, FrameKind, ShardJobMsg, ShardResultMsg, WireDataset, WireError,
    WireGoodbye, WireHeartbeat, WireHello, WirePlan, WireRequest, WireShardSpec, HEADER_LEN,
    TRAILER_LEN, WIRE_CONTROL_VERSION, WIRE_VERSION,
};

fn unhex(parts: &[&str]) -> Vec<u8> {
    let joined: String = parts.concat();
    assert!(joined.len() % 2 == 0);
    (0..joined.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&joined[i..i + 2], 16).unwrap())
        .collect()
}

/// Golden 1: an f32-payload job of an unplanned run (threads pinned).
const JOB_F32: &[&str] = &[
    "45424357020001005c0000000100000002000000100000000600000067726565",
    "6479000001010102000000000300000003000000000000000500000000000000",
    "080000000000000003000000020000000000803f000000c00000003f00005040",
    "000040bf00008040961f66b1",
];

fn job_f32() -> ShardJobMsg {
    ShardJobMsg {
        shard: 1,
        k: 2,
        batch: 16,
        optimizer: "greedy".into(),
        payload: Precision::F32,
        precision: Precision::F32,
        cpu_kernel: CpuKernel::Blocked,
        kernel: KernelImpl::Jnp,
        threads: Some(2),
        plan: None,
        ground_ids: vec![3, 5, 8],
        data: Matrix::from_vec(3, 2, vec![1.0, -2.0, 0.5, 3.25, -0.75, 4.0]),
    }
}

/// Golden 2: a bf16-payload job of a planned run (serialized plan core).
const JOB_BF16_PLANNED: &[&str] = &[
    "45424357020001006c0000000000000001000000080000000b0000006c617a79",
    "5f67726565647901010000000000000001400000000800000004000000030000",
    "0001010108000000040000000200000008000000020000000000000000000000",
    "02000000000000000200000002000000803f00c0203e40400c614240",
];

fn job_bf16_planned() -> ShardJobMsg {
    ShardJobMsg {
        shard: 0,
        k: 1,
        batch: 8,
        optimizer: "lazy_greedy".into(),
        payload: Precision::Bf16,
        precision: Precision::Bf16,
        cpu_kernel: CpuKernel::Scalar,
        kernel: KernelImpl::Pallas,
        threads: None,
        plan: Some(WirePlan {
            n: 64,
            d: 8,
            shards: 4,
            k: 3,
            precision: Precision::Bf16,
            kernel: KernelImpl::Jnp,
            cpu_kernel: CpuKernel::Blocked,
            cores: 8,
            shard_workers: 4,
            oracle_threads: 2,
            merge_threads: 8,
        }),
        ground_ids: vec![0, 2],
        // every value is bf16-representable, so the frame is lossless
        data: Matrix::from_vec(2, 2, vec![1.0, -2.0, 0.15625, 3.0]),
    }
}

/// Golden 9 (PR 9): an f32 job selecting the `simd` cpu kernel
/// (code 2) — the code set grew but the v2 layout is byte-identical,
/// so only this new frame was minted; goldens 1–8 are untouched.
const JOB_SIMD: &[&str] = &[
    "45424357020001004c0000000300000002000000200000000600000067726565",
    "6479000002010104000000000200000001000000000000000400000000000000",
    "02000000020000000000003f0000c0bf00000040000080bebffc1499",
];

fn job_simd() -> ShardJobMsg {
    ShardJobMsg {
        shard: 3,
        k: 2,
        batch: 32,
        optimizer: "greedy".into(),
        payload: Precision::F32,
        precision: Precision::F32,
        cpu_kernel: CpuKernel::Simd,
        kernel: KernelImpl::Jnp,
        threads: Some(4),
        plan: None,
        ground_ids: vec![1, 4],
        data: Matrix::from_vec(2, 2, vec![0.5, -1.5, 2.0, -0.25]),
    }
}

/// Golden 3: a result frame.
const RESULT: &[&str] = &[
    "454243570200020050000000020000000a000000030000000700000000000000",
    "03000000000000000900000000000000030000000000003f0000403f0000803f",
    "0000803f000000000000d03f2a00000000000000d20400000000000077354eae",
];

fn result_msg() -> ShardResultMsg {
    ShardResultMsg {
        shard: 2,
        size: 10,
        indices: vec![7, 3, 9],
        f_trajectory: vec![0.5, 0.75, 1.0],
        f_final: 1.0,
        wall_seconds: 0.25,
        oracle_calls: 42,
        oracle_work: 1234,
    }
}

/// Golden 4 (v2): a planned, sharded request over a synthetic dataset
/// reference — the frame a client hands the future TCP listener.
const REQUEST_SYNTHETIC: &[&str] = &[
    "4542435702000300600000000500000000020000060000006772656564790001",
    "02000000bc0e000000000000010104000000080000006c6f63616c6974790000",
    "000000000000080000006c6f6f706261636b03000000010800000001e8030000",
    "200000002a00000000000000a904221e",
];

fn request_synthetic() -> WireRequest {
    WireRequest {
        k: 5,
        batch: 512,
        optimizer: "greedy".into(),
        precision: Precision::F32,
        cpu_kernel: CpuKernel::Blocked,
        threads: 2,
        seed: 0xEBC,
        with_baseline: true,
        shard: Some(WireShardSpec {
            partitions: 4,
            partitioner: "locality".into(),
            per_shard_k: 0,
            threads: 0,
            transport: "loopback".into(),
            replicas: 3,
            plan: true,
            cores: 8,
        }),
        dataset: WireDataset::Synthetic { n: 1000, d: 32, seed: 42 },
    }
}

/// Golden 5 (v2): a single-node request with an inline bf16 dataset
/// (every value bf16-representable, so the frame is lossless).
const REQUEST_INLINE_BF16: &[&str] = &[
    "45424357020003004100000002000000400000000f00000073696576655f7374",
    "7265616d696e6701000000000007000000000000000000000102000000030000",
    "00803f00c0203e4040003f80be4e1bb1c1",
];

fn request_inline_bf16() -> WireRequest {
    WireRequest {
        k: 2,
        batch: 64,
        optimizer: "sieve_streaming".into(),
        precision: Precision::Bf16,
        cpu_kernel: CpuKernel::Scalar,
        threads: 0,
        seed: 7,
        with_baseline: false,
        shard: None,
        dataset: WireDataset::Inline {
            payload: Precision::Bf16,
            data: Matrix::from_vec(2, 3, vec![1.0, -2.0, 0.15625, 3.0, 0.5, -0.25]),
        },
    }
}

/// Golden 6 (v3): the hello a replica sends on accept.
const HELLO: &[&str] = &[
    "454243570300040011000000090000007265706c6963612d3704000000bf6849",
    "fb",
];

fn hello_msg() -> WireHello {
    WireHello { id: "replica-7".into(), capacity: 4 }
}

/// Golden 7 (v3): a liveness heartbeat.
const HEARTBEAT: &[&str] = &[
    "454243570300050015000000090000007265706c6963612d372a000000000000",
    "004ee58850",
];

fn heartbeat_msg() -> WireHeartbeat {
    WireHeartbeat { id: "replica-7".into(), seq: 42 }
}

/// Golden 8 (v3): a draining goodbye.
const GOODBYE: &[&str] = &[
    "454243570300060024000000090000007265706c6963612d3701120000006d61",
    "696e74656e616e63652077696e646f77518c5fc3",
];

fn goodbye_msg() -> WireGoodbye {
    WireGoodbye { id: "replica-7".into(), drain: true, detail: "maintenance window".into() }
}

/// Recompute a frame's CRC trailer after patching its body, so tests
/// reach the check they target instead of tripping the checksum.
fn reseal(frame: &mut [u8]) {
    let body_len = frame.len() - TRAILER_LEN;
    let crc = crc32(&frame[..body_len]);
    frame[body_len..].copy_from_slice(&crc.to_le_bytes());
}

// ----------------------------------------------------------- conformance

#[test]
fn encode_reproduces_goldens_byte_for_byte() {
    assert_eq!(
        encode_job(&job_f32()),
        unhex(JOB_F32),
        "f32 job frame drifted — bump WIRE_VERSION and regenerate goldens"
    );
    assert_eq!(
        encode_job(&job_bf16_planned()),
        unhex(JOB_BF16_PLANNED),
        "bf16/planned job frame drifted — bump WIRE_VERSION and regenerate goldens"
    );
    assert_eq!(
        encode_job(&job_simd()),
        unhex(JOB_SIMD),
        "simd job frame drifted — bump WIRE_VERSION and regenerate goldens"
    );
    assert_eq!(
        encode_result(&result_msg()),
        unhex(RESULT),
        "result frame drifted — bump WIRE_VERSION and regenerate goldens"
    );
    assert_eq!(
        encode_request(&request_synthetic()),
        unhex(REQUEST_SYNTHETIC),
        "synthetic request frame drifted — bump WIRE_VERSION and regenerate goldens"
    );
    assert_eq!(
        encode_request(&request_inline_bf16()),
        unhex(REQUEST_INLINE_BF16),
        "inline-bf16 request frame drifted — bump WIRE_VERSION and regenerate goldens"
    );
}

#[test]
fn decode_reproduces_the_expected_structs() {
    assert_eq!(decode_job(&unhex(JOB_F32)).unwrap(), job_f32());
    assert_eq!(decode_job(&unhex(JOB_BF16_PLANNED)).unwrap(), job_bf16_planned());
    assert_eq!(decode_job(&unhex(JOB_SIMD)).unwrap(), job_simd());
    assert_eq!(decode_result(&unhex(RESULT)).unwrap(), result_msg());
    assert_eq!(decode_request(&unhex(REQUEST_SYNTHETIC)).unwrap(), request_synthetic());
    assert_eq!(
        decode_request(&unhex(REQUEST_INLINE_BF16)).unwrap(),
        request_inline_bf16()
    );
}

#[test]
fn frame_kind_classifies_goldens() {
    assert_eq!(frame_kind(&unhex(JOB_F32)).unwrap(), FrameKind::Job);
    assert_eq!(frame_kind(&unhex(JOB_BF16_PLANNED)).unwrap(), FrameKind::Job);
    assert_eq!(frame_kind(&unhex(JOB_SIMD)).unwrap(), FrameKind::Job);
    assert_eq!(frame_kind(&unhex(RESULT)).unwrap(), FrameKind::Result);
    assert_eq!(frame_kind(&unhex(REQUEST_SYNTHETIC)).unwrap(), FrameKind::Request);
    assert_eq!(frame_kind(&unhex(REQUEST_INLINE_BF16)).unwrap(), FrameKind::Request);
}

#[test]
fn control_encode_reproduces_goldens_byte_for_byte() {
    assert_eq!(
        encode_hello(&hello_msg()),
        unhex(HELLO),
        "hello frame drifted — bump WIRE_CONTROL_VERSION and regenerate goldens"
    );
    assert_eq!(
        encode_heartbeat(&heartbeat_msg()),
        unhex(HEARTBEAT),
        "heartbeat frame drifted — bump WIRE_CONTROL_VERSION and regenerate goldens"
    );
    assert_eq!(
        encode_goodbye(&goodbye_msg()),
        unhex(GOODBYE),
        "goodbye frame drifted — bump WIRE_CONTROL_VERSION and regenerate goldens"
    );
}

#[test]
fn control_decode_reproduces_the_expected_structs() {
    assert_eq!(decode_hello(&unhex(HELLO)).unwrap(), hello_msg());
    assert_eq!(decode_heartbeat(&unhex(HEARTBEAT)).unwrap(), heartbeat_msg());
    assert_eq!(decode_goodbye(&unhex(GOODBYE)).unwrap(), goodbye_msg());
}

#[test]
fn control_frame_kind_classifies_goldens() {
    assert_eq!(frame_kind(&unhex(HELLO)).unwrap(), FrameKind::Hello);
    assert_eq!(frame_kind(&unhex(HEARTBEAT)).unwrap(), FrameKind::Heartbeat);
    assert_eq!(frame_kind(&unhex(GOODBYE)).unwrap(), FrameKind::Goodbye);
}

#[test]
fn golden_checksums_verify_independently() {
    // the last four bytes of every golden are the CRC-32 of the rest
    for golden in [
        &unhex(JOB_F32),
        &unhex(JOB_BF16_PLANNED),
        &unhex(JOB_SIMD),
        &unhex(RESULT),
        &unhex(REQUEST_SYNTHETIC),
        &unhex(REQUEST_INLINE_BF16),
        &unhex(HELLO),
        &unhex(HEARTBEAT),
        &unhex(GOODBYE),
    ] {
        let body = &golden[..golden.len() - TRAILER_LEN];
        let stored = u32::from_le_bytes(golden[golden.len() - TRAILER_LEN..].try_into().unwrap());
        assert_eq!(crc32(body), stored);
    }
}

#[test]
fn imm_dataset_requests_roundtrip() {
    // not golden-pinned (the shape is covered by the goldens above) but
    // the part/state enum codes must survive the trip
    let req = WireRequest {
        dataset: WireDataset::Imm {
            part: Part::Plate,
            state: ProcessState::Downtimes,
            samples: 3524,
            seed: 7,
        },
        ..request_synthetic()
    };
    let frame = encode_request(&req);
    assert_eq!(decode_request(&frame).unwrap(), req);
}

// ------------------------------------------------------------ corruption

#[test]
fn truncated_frames_are_typed_errors_never_panics() {
    let golden = unhex(JOB_BF16_PLANNED);
    for len in 0..golden.len() {
        match decode_job(&golden[..len]) {
            Err(WireError::TooShort { .. }) | Err(WireError::LengthMismatch { .. }) => {}
            other => panic!("truncated to {len}: {other:?}"),
        }
    }
    // dropping the trailer alone is a length mismatch, not a crash
    let no_trailer = &golden[..golden.len() - TRAILER_LEN];
    assert!(matches!(
        decode_job(no_trailer),
        Err(WireError::TooShort { .. }) | Err(WireError::LengthMismatch { .. })
    ));
}

#[test]
fn every_bit_flip_in_every_golden_is_detected() {
    enum Kind {
        Job,
        Result,
        Request,
    }
    for (golden, kind) in [
        (unhex(JOB_F32), Kind::Job),
        (unhex(JOB_SIMD), Kind::Job),
        (unhex(RESULT), Kind::Result),
        (unhex(REQUEST_SYNTHETIC), Kind::Request),
    ] {
        for byte in 0..golden.len() {
            for bit in 0..8 {
                let mut bad = golden.clone();
                bad[byte] ^= 1 << bit;
                let err = match kind {
                    Kind::Job => decode_job(&bad).err(),
                    Kind::Result => decode_result(&bad).err(),
                    Kind::Request => decode_request(&bad).err(),
                };
                assert!(err.is_some(), "flip byte {byte} bit {bit} went undetected");
            }
        }
    }
}

#[test]
fn unknown_version_frames_are_rejected_up_front() {
    // frames from a hypothetical v3 encoder AND from the retired v1:
    // version bytes patched, checksum re-sealed so *only* the version
    // check can reject them
    for found in [1u16, 3] {
        let mut other = unhex(JOB_F32);
        other[4..6].copy_from_slice(&found.to_le_bytes());
        let body_len = other.len() - TRAILER_LEN;
        let crc = crc32(&other[..body_len]);
        other[body_len..].copy_from_slice(&crc.to_le_bytes());
        assert_eq!(
            decode_job(&other).unwrap_err(),
            WireError::UnsupportedVersion { found, supported: WIRE_VERSION }
        );
    }
}

#[test]
fn unknown_kind_and_kind_confusion_are_typed() {
    let mut alien = unhex(RESULT);
    alien[6] = 9;
    let body_len = alien.len() - TRAILER_LEN;
    let crc = crc32(&alien[..body_len]);
    alien[body_len..].copy_from_slice(&crc.to_le_bytes());
    assert_eq!(decode_result(&alien).unwrap_err(), WireError::UnknownKind(9));
    // a valid job frame handed to the result decoder (and vice versa)
    assert!(matches!(
        decode_result(&unhex(JOB_F32)),
        Err(WireError::Malformed { field: "kind", .. })
    ));
    assert!(matches!(
        decode_job(&unhex(RESULT)),
        Err(WireError::Malformed { field: "kind", .. })
    ));
}

#[test]
fn bad_magic_is_typed() {
    let mut bad = unhex(JOB_F32);
    bad[0] = b'X';
    assert!(matches!(decode_job(&bad), Err(WireError::BadMagic { .. })));
}

#[test]
fn appended_garbage_is_a_length_mismatch() {
    let mut frame = unhex(RESULT);
    let declared = frame.len() - HEADER_LEN - TRAILER_LEN;
    frame.extend_from_slice(&[0xAB; 7]);
    assert_eq!(
        decode_result(&frame).unwrap_err(),
        WireError::LengthMismatch { declared, available: declared + 7 }
    );
}

#[test]
fn corrupt_enum_bytes_inside_a_resealed_payload_are_malformed() {
    // corrupt the cpu_kernel byte (payload offset 24: 12 fixed + 10 str
    // + payload_precision + precision) and re-seal the checksum so the
    // field validator itself must catch it
    let mut bad = unhex(JOB_F32);
    bad[HEADER_LEN + 24] = 7;
    let body_len = bad.len() - TRAILER_LEN;
    let crc = crc32(&bad[..body_len]);
    bad[body_len..].copy_from_slice(&crc.to_le_bytes());
    assert!(matches!(
        decode_job(&bad),
        Err(WireError::Malformed { field: "cpu_kernel", .. })
    ));
}

#[test]
fn truncated_control_frames_are_typed_errors_never_panics() {
    let golden = unhex(GOODBYE);
    for len in 0..golden.len() {
        match decode_goodbye(&golden[..len]) {
            Err(WireError::TooShort { .. }) | Err(WireError::LengthMismatch { .. }) => {}
            other => panic!("truncated to {len}: {other:?}"),
        }
    }
}

#[test]
fn every_bit_flip_in_every_control_golden_is_detected() {
    enum Kind {
        Hello,
        Heartbeat,
        Goodbye,
    }
    for (golden, kind) in [
        (unhex(HELLO), Kind::Hello),
        (unhex(HEARTBEAT), Kind::Heartbeat),
        (unhex(GOODBYE), Kind::Goodbye),
    ] {
        for byte in 0..golden.len() {
            for bit in 0..8 {
                let mut bad = golden.clone();
                bad[byte] ^= 1 << bit;
                let err = match kind {
                    Kind::Hello => decode_hello(&bad).err(),
                    Kind::Heartbeat => decode_heartbeat(&bad).err(),
                    Kind::Goodbye => decode_goodbye(&bad).err(),
                };
                assert!(err.is_some(), "flip byte {byte} bit {bit} went undetected");
            }
        }
    }
}

#[test]
fn control_and_data_versions_never_cross_pair() {
    // a hello claiming the data version (resealed so only the pairing
    // check can reject it)...
    let mut hello_v2 = unhex(HELLO);
    hello_v2[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    reseal(&mut hello_v2);
    assert_eq!(
        decode_hello(&hello_v2).unwrap_err(),
        WireError::UnsupportedVersion { found: WIRE_VERSION, supported: WIRE_CONTROL_VERSION }
    );
    // ...and a result claiming the control version
    let mut result_v3 = unhex(RESULT);
    result_v3[4..6].copy_from_slice(&WIRE_CONTROL_VERSION.to_le_bytes());
    reseal(&mut result_v3);
    assert_eq!(
        decode_result(&result_v3).unwrap_err(),
        WireError::UnsupportedVersion { found: WIRE_CONTROL_VERSION, supported: WIRE_VERSION }
    );
}

#[test]
fn control_kind_confusion_is_typed() {
    // valid control frames handed to the wrong control decoder, and a
    // data frame handed to a control decoder
    assert!(matches!(
        decode_heartbeat(&unhex(HELLO)),
        Err(WireError::Malformed { field: "kind", .. })
    ));
    assert!(matches!(
        decode_goodbye(&unhex(HEARTBEAT)),
        Err(WireError::Malformed { field: "kind", .. })
    ));
    assert!(matches!(
        decode_hello(&unhex(JOB_F32)),
        Err(WireError::Malformed { field: "kind", .. })
    ));
    assert!(matches!(
        decode_job(&unhex(HELLO)),
        Err(WireError::Malformed { field: "kind", .. })
    ));
}

#[test]
fn control_version_is_three_until_consciously_bumped() {
    assert_eq!(WIRE_CONTROL_VERSION, 3);
    // every control golden carries it in its version bytes
    for golden in [&unhex(HELLO), &unhex(HEARTBEAT), &unhex(GOODBYE)] {
        assert_eq!(
            u16::from_le_bytes(golden[4..6].try_into().unwrap()),
            WIRE_CONTROL_VERSION
        );
    }
}

#[test]
fn wire_version_is_two_until_consciously_bumped() {
    // the goldens above encode version 2 (v1 + the request frame kind);
    // this pin makes a version bump show up here too, next to the
    // regeneration instructions
    assert_eq!(WIRE_VERSION, 2);
}
