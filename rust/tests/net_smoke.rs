//! End-to-end smoke tests for the socket leg: a real localhost replica
//! fleet behind [`TcpReplicaTransport`], exercised through the sharded
//! summarizer and directly through `run_jobs`.
//!
//! The soak test is the PR's acceptance criterion: under seeded chaos
//! the run must finish with exemplars bit-identical to the in-process
//! path (directly, or via the flagged degraded fallback) or a typed
//! error — never a panic, never an unbounded hang.

use ebc::engine::{KernelImpl, OracleSpec, Precision};
use ebc::linalg::{CpuKernel, Matrix, SharedMatrix};
use ebc::optim::Greedy;
use ebc::shard::{
    build_partitioner, spawn_replica, ExecCtx, NetOptions, ServerHandle, ShardJobMsg,
    ShardTransport, ShardedResult, ShardedSummarizer, TcpReplicaTransport, TransportError,
};
use ebc::submodular::{CpuOracle, Oracle};
use ebc::util::rng::Rng;
use std::net::TcpListener;
use std::sync::Arc;

fn oracle_factory(m: SharedMatrix, _spec: &OracleSpec) -> Box<dyn Oracle> {
    Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
}

fn replica(id: &str, capacity: u32) -> ServerHandle {
    spawn_replica("127.0.0.1:0", id, capacity, 1, &NetOptions::default(), oracle_factory)
        .expect("bind an ephemeral-port replica")
}

/// Fast-failing knobs so dead-endpoint tests spend milliseconds, not
/// the production deadlines.
fn fast_opts(addrs: Vec<String>) -> NetOptions {
    NetOptions {
        addrs,
        connect_timeout_ms: 300,
        io_timeout_ms: 2000,
        retries: 1,
        backoff_ms: 1,
        ..NetOptions::default()
    }
}

/// An address nothing listens on: bind an ephemeral port, then drop the
/// listener.
fn dead_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").expect("bind a throwaway port");
    let addr = l.local_addr().expect("resolve the throwaway port").to_string();
    drop(l);
    addr
}

fn dataset(n: usize, d: usize, seed: u64) -> SharedMatrix {
    Arc::new(Matrix::random_normal(n, d, &mut Rng::new(seed)))
}

/// Run the two-stage pipeline over `transport` (None = in-process).
fn summarize(
    v: &SharedMatrix,
    transport: Option<&dyn ShardTransport>,
    shards: usize,
    k: usize,
) -> ShardedResult {
    let part = build_partitioner("hash", 11).expect("hash partitioner");
    let greedy = Greedy::default();
    let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, shards);
    s.transport = transport;
    s.summarize(v, &oracle_factory, k)
}

fn assert_same_selection(got: &ShardedResult, want: &ShardedResult, label: &str) {
    assert_eq!(got.merged.indices, want.merged.indices, "{label}: exemplar indices diverged");
    assert_eq!(
        got.merged.f_final.to_bits(),
        want.merged.f_final.to_bits(),
        "{label}: f bits diverged"
    );
}

fn raw_jobs(n_jobs: usize, rows: usize, seed: u64) -> Vec<ShardJobMsg> {
    let mut rng = Rng::new(seed);
    (0..n_jobs)
        .map(|s| ShardJobMsg {
            shard: s as u32,
            k: 2,
            batch: 64,
            optimizer: "greedy".into(),
            payload: Precision::F32,
            precision: Precision::F32,
            cpu_kernel: CpuKernel::Scalar,
            kernel: KernelImpl::Jnp,
            threads: None,
            plan: None,
            ground_ids: (0..rows as u64).map(|i| i + 100 * s as u64).collect(),
            data: Matrix::random_normal(rows, 3, &mut rng),
        })
        .collect()
}

#[test]
fn tcp_fleet_reproduces_the_inproc_selection() {
    let v = dataset(36, 4, 0xA11CE);
    let want = summarize(&v, None, 4, 3);

    let servers = vec![replica("smoke-a", 1), replica("smoke-b", 2), replica("smoke-c", 1)];
    let tcp = TcpReplicaTransport::new(NetOptions {
        addrs: servers.iter().map(|s| s.addr()).collect(),
        ..NetOptions::default()
    });
    let res = summarize(&v, Some(&tcp), 4, 3);

    assert_same_selection(&res, &want, "healthy fleet");
    assert_eq!(res.transport, "tcp");
    assert!(!res.degraded, "a healthy fleet must not report degradation");
    assert!(res.wire_bytes > 0, "tcp traffic went unaccounted");
    assert_eq!(res.shard_retries, 0, "a healthy fleet re-queued shards");

    // the hello frames refined the registry: smoke-b advertised
    // capacity 2, and the fleet as a whole did all the stage-1 work
    let b_addr = servers[1].addr();
    tcp.with_registry(|r| {
        assert_eq!(r.get_mut(&b_addr).expect("smoke-b registered").capacity, 2);
        let done: u64 = r.iter().map(|rep| rep.jobs_done).sum();
        assert_eq!(done, res.shards_used as u64);
    });

    let served: u64 = servers.into_iter().map(|s| s.stop()).sum();
    assert_eq!(served, res.shards_used as u64, "replica job counters disagree with the run");
}

#[test]
fn dead_endpoint_requeues_to_the_survivor() {
    let v = dataset(30, 3, 0xBEEF);
    let want = summarize(&v, None, 4, 2);

    let survivor = replica("smoke-survivor", 1);
    let tcp = TcpReplicaTransport::new(fast_opts(vec![dead_addr(), survivor.addr()]));
    let res = summarize(&v, Some(&tcp), 4, 2);

    assert_same_selection(&res, &want, "one-dead-endpoint fleet");
    assert!(!res.degraded, "one survivor is a working fleet, not a degraded one");
    assert_eq!(res.transport, "tcp");
    tcp.with_registry(|r| assert_eq!(r.alive(), 1, "the dead endpoint was not killed"));
    survivor.stop();
}

#[test]
fn unreachable_fleet_degrades_but_still_answers() {
    let v = dataset(24, 3, 0xD00D);
    let want = summarize(&v, None, 3, 2);

    let tcp = TcpReplicaTransport::new(fast_opts(vec![dead_addr(), dead_addr()]));

    // the raw transport reports the typed fleet-loss error…
    let jobs = raw_jobs(2, 8, 9);
    let ctx = ExecCtx::remote(&oracle_factory, 1);
    match tcp.run_jobs(&jobs, &ctx) {
        Err(TransportError::NoReplicas { unassigned }) => assert!(unassigned > 0),
        other => panic!("expected NoReplicas, got {other:?}"),
    }

    // …and the summarizer turns it into a flagged in-process fallback
    // with the same answer (fresh transport: the first run killed the
    // fleet in the registry)
    let tcp = TcpReplicaTransport::new(fast_opts(vec![dead_addr(), dead_addr()]));
    let res = summarize(&v, Some(&tcp), 3, 2);
    assert!(res.degraded, "an unreachable fleet must flag the degradation");
    assert_eq!(res.transport, "inproc", "the fallback transport name leaks");
    assert_same_selection(&res, &want, "degraded fallback");
}

#[test]
fn poison_job_is_a_final_typed_replica_error() {
    let server = replica("smoke-poison", 1);
    let tcp = TcpReplicaTransport::new(fast_opts(vec![server.addr()]));
    let mut jobs = raw_jobs(1, 8, 3);
    jobs[0].optimizer = "no-such-optimizer".into();
    let ctx = ExecCtx::remote(&oracle_factory, 1);
    match tcp.run_jobs(&jobs, &ctx) {
        Err(TransportError::Replica { id, detail }) => {
            assert_eq!(id, "smoke-poison");
            assert!(
                detail.contains("no-such-optimizer"),
                "goodbye detail lost the cause: {detail}"
            );
        }
        other => panic!("expected a Replica error, got {other:?}"),
    }
    // deterministic failure: the replica is not killed, the job is not
    // retried elsewhere
    tcp.with_registry(|r| assert_eq!(r.alive(), 1));
    server.stop();
}

#[test]
fn chaos_soak_identity_or_typed_error_never_panic() {
    let v = dataset(24, 3, 0x50AC);
    let want = summarize(&v, None, 3, 2);

    let servers = vec![replica("soak-a", 1), replica("soak-b", 1)];
    let addrs: Vec<String> = servers.iter().map(|s| s.addr()).collect();

    // control: chaos 0 is the plain socket leg — no retries, no
    // degradation, identical selection
    let clean = TcpReplicaTransport::new(fast_opts(addrs.clone()));
    let res = summarize(&v, Some(&clean), 3, 2);
    assert_same_selection(&res, &want, "chaos control");
    assert!(!res.degraded && res.shard_retries == 0, "chaos-free control run saw faults");

    for seed in 1..=4u64 {
        // fresh transport per seed: a seed that kills the whole fleet
        // must not poison the next seed's registry
        let opts = NetOptions { chaos: seed, ..fast_opts(addrs.clone()) };
        let tcp = TcpReplicaTransport::new(opts);
        let res = summarize(&v, Some(&tcp), 3, 2);
        // whatever the fault schedule did — retries, re-queues, a full
        // fleet loss absorbed by the flagged fallback — the selection is
        // bit-identical and the run terminated inside its deadlines
        assert_same_selection(&res, &want, &format!("chaos seed {seed}"));
        if res.degraded {
            // fleet loss: every endpoint must actually be dead
            tcp.with_registry(|r| {
                assert_eq!(r.alive(), 0, "seed {seed}: degraded with live replicas")
            });
        }
    }

    // the servers survived every fault schedule thrown at them
    for s in servers {
        let _ = s.stop();
    }
}
