//! End-to-end runtime tests: AOT HLO artifacts loaded through PJRT must
//! reproduce the CPU reference implementation bit-for-bit-ish (f32
//! tolerance) across the whole Oracle surface, for both precisions.
//!
//! **Gate: `RUN_E2E=1`.** These tests need the real `xla` crate and the
//! AOT artifacts (`make artifacts`); the offline stub build cannot run
//! the XLA backend. Without the gate each test prints a skip line and
//! returns green, so CI output shows *why* nothing executed. With the
//! gate but without artifacts, `runtime()` panics with the remedy.

use ebc::engine::{DeviceDataset, Engine, EngineConfig, Precision, XlaOracle};
use ebc::util::testing::e2e_enabled;
use ebc::linalg::Matrix;
use ebc::optim::{Greedy, Optimizer, ThreeSieves};
use ebc::runtime::Runtime;
use ebc::submodular::{fold_mindist, CpuOracle, EbcFunction, Oracle};
use ebc::util::rng::Rng;

fn runtime() -> Runtime {
    match Runtime::discover() {
        Ok(rt) => rt,
        Err(e) => panic!("artifacts missing — run `make artifacts` first: {e}"),
    }
}

fn engine(p: Precision) -> Engine {
    Engine::new(runtime(), EngineConfig { precision: p, cpu_fallback: false, ..Default::default() })
}

fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[test]
fn gains_match_cpu_f32() {
    if !e2e_enabled("gains_match_cpu_f32") {
        return;
    }
    let mut rng = Rng::new(1);
    let v = Matrix::random_normal(500, 100, &mut rng);
    let f = EbcFunction::new(v.clone());
    let mut ds = DeviceDataset::new(v.clone());
    let eng = engine(Precision::F32);

    // non-trivial state: two selections folded in
    let mut mindist = f.vsq().to_vec();
    fold_mindist(&mut mindist, &f.dist_col(3));
    fold_mindist(&mut mindist, &f.dist_col(77));

    let cands: Vec<usize> = vec![0, 9, 99, 250, 499];
    let cpu = f.gains(&mindist, &cands);
    let cmat = v.gather(&cands);
    let xla = eng.gains(&mut ds, &mindist, &cmat).unwrap();
    assert_eq!(xla.len(), cands.len());
    for (i, (&a, &b)) in cpu.iter().zip(&xla).enumerate() {
        assert!(close(a, b, 1e-4), "cand {i}: cpu {a} xla {b}");
    }
}

#[test]
fn gains_bf16_close_to_f32() {
    if !e2e_enabled("gains_bf16_close_to_f32") {
        return;
    }
    let mut rng = Rng::new(2);
    let v = Matrix::random_normal(300, 100, &mut rng);
    let f = EbcFunction::new(v.clone());
    let mindist = f.vsq().to_vec();
    let cands: Vec<usize> = (0..50).collect();
    let cpu = f.gains(&mindist, &cands);

    let eng = engine(Precision::Bf16);
    let mut ds = DeviceDataset::new(v.clone());
    let xla = eng.gains(&mut ds, &mindist, &v.gather(&cands)).unwrap();
    // bf16 has ~3 decimal digits; distances are O(d)=O(100)
    for (i, (&a, &b)) in cpu.iter().zip(&xla).enumerate() {
        assert!(close(a, b, 3e-2), "cand {i}: cpu {a} bf16 {b}");
    }
}

#[test]
fn update_and_dist_col_match_cpu() {
    if !e2e_enabled("update_and_dist_col_match_cpu") {
        return;
    }
    let mut rng = Rng::new(3);
    let v = Matrix::random_normal(400, 100, &mut rng);
    let f = EbcFunction::new(v.clone());
    let eng = engine(Precision::F32);
    let mut ds = DeviceDataset::new(v.clone());

    // dist_col via +BIG trick
    let dcol_cpu = f.dist_col(42);
    let dcol_xla = eng.dist_col_vec(&mut ds, v.row(42)).unwrap();
    for i in 0..dcol_cpu.len() {
        assert!(close(dcol_cpu[i], dcol_xla[i], 1e-4), "i={i}");
    }

    // update folds + returns f
    let mut mindist = f.vsq().to_vec();
    let (nm, fval) = eng.update(&mut ds, &mindist, v.row(42)).unwrap();
    fold_mindist(&mut mindist, &dcol_cpu);
    for i in 0..nm.len() {
        assert!(close(mindist[i], nm[i], 1e-4), "i={i}");
    }
    let f_direct = f.eval(&[42]);
    assert!(close(fval, f_direct, 1e-4), "{fval} vs {f_direct}");
}

#[test]
fn eval_sets_match_cpu_work_matrix() {
    if !e2e_enabled("eval_sets_match_cpu_work_matrix") {
        return;
    }
    let mut rng = Rng::new(4);
    let v = Matrix::random_normal(700, 100, &mut rng);
    let f = EbcFunction::new(v.clone());
    let eng = engine(Precision::F32);
    let mut ds = DeviceDataset::new(v.clone());

    // ragged sets, incl. singleton and larger ones
    let sets: Vec<Vec<usize>> = vec![
        vec![0],
        vec![1, 2, 3],
        vec![600, 5, 99, 320, 17],
        (0..16).collect(),
        vec![699],
    ];
    let refs: Vec<&[usize]> = sets.iter().map(|s| s.as_slice()).collect();
    let cpu = f.eval_sets_st(&refs);
    let xla = eng.eval_sets(&mut ds, &refs).unwrap();
    for i in 0..cpu.len() {
        assert!(close(cpu[i], xla[i], 1e-4), "set {i}: cpu {} xla {}", cpu[i], xla[i]);
    }
}

#[test]
fn greedy_on_xla_matches_greedy_on_cpu() {
    if !e2e_enabled("greedy_on_xla_matches_greedy_on_cpu") {
        return;
    }
    let mut rng = Rng::new(5);
    let v = Matrix::random_normal(600, 100, &mut rng);
    let g_cpu = Greedy { batch: 256 }.run(&mut CpuOracle::new(v.clone()), 8);
    let mut xo = XlaOracle::new(engine(Precision::F32), v);
    let g_xla = Greedy { batch: 256 }.run(&mut xo, 8);
    assert_eq!(g_cpu.indices, g_xla.indices, "selection paths diverged");
    assert!(close(g_cpu.f_final, g_xla.f_final, 1e-4));
}

#[test]
fn three_sieves_on_xla_close_to_cpu() {
    if !e2e_enabled("three_sieves_on_xla_close_to_cpu") {
        return;
    }
    let mut rng = Rng::new(6);
    let v = Matrix::random_normal(400, 100, &mut rng);
    let ts = ThreeSieves { epsilon: 0.1, t: 20 };
    let r_cpu = ts.run(&mut CpuOracle::new(v.clone()), 5);
    let mut xo = XlaOracle::new(engine(Precision::F32), v);
    let r_xla = ts.run(&mut xo, 5);
    assert_eq!(r_cpu.indices, r_xla.indices);
    assert!(close(r_cpu.f_final, r_xla.f_final, 1e-3));
}

#[test]
fn padded_d_dimension_is_exact() {
    if !e2e_enabled("padded_d_dimension_is_exact") {
        return;
    }
    // d=37 pads to the d=128 bucket; zero-padding must not change values
    let mut rng = Rng::new(7);
    let v = Matrix::random_normal(100, 37, &mut rng);
    let f = EbcFunction::new(v.clone());
    let eng = engine(Precision::F32);
    let mut ds = DeviceDataset::new(v.clone());
    let sets: Vec<Vec<usize>> = vec![vec![5, 50], vec![99]];
    let refs: Vec<&[usize]> = sets.iter().map(|s| s.as_slice()).collect();
    let cpu = f.eval_sets_st(&refs);
    let xla = eng.eval_sets(&mut ds, &refs).unwrap();
    for i in 0..cpu.len() {
        assert!(close(cpu[i], xla[i], 1e-4));
    }
}

#[test]
fn oversized_request_errors_without_fallback() {
    if !e2e_enabled("oversized_request_errors_without_fallback") {
        return;
    }
    let mut rng = Rng::new(8);
    let v = Matrix::random_normal(64, 8, &mut rng);
    let eng = engine(Precision::F32);
    let mut ds = DeviceDataset::new(v);
    // k=2000 exceeds every eval_multi bucket
    let big: Vec<usize> = (0..64).cycle().take(2000).collect();
    let sets: Vec<&[usize]> = vec![&big];
    assert!(eng.eval_sets(&mut ds, &sets).is_err());
}

#[test]
fn cpu_fallback_handles_oversized() {
    if !e2e_enabled("cpu_fallback_handles_oversized") {
        return;
    }
    let mut rng = Rng::new(9);
    let v = Matrix::random_normal(64, 8, &mut rng);
    let f = EbcFunction::new(v.clone());
    let eng = Engine::new(runtime(), EngineConfig { precision: Precision::F32, cpu_fallback: true, ..Default::default() });
    let mut ds = DeviceDataset::new(v);
    let big: Vec<usize> = (0..64).cycle().take(2000).collect();
    let sets: Vec<&[usize]> = vec![&big];
    let got = eng.eval_sets(&mut ds, &sets).unwrap();
    let want = f.eval_sets_st(&sets);
    assert!(close(got[0], want[0], 1e-4));
}

#[test]
fn pallas_and_jnp_impls_agree() {
    if !e2e_enabled("pallas_and_jnp_impls_agree") {
        return;
    }
    use ebc::engine::KernelImpl;
    let mut rng = Rng::new(11);
    let v = Matrix::random_normal(600, 100, &mut rng);
    let f = EbcFunction::new(v.clone());
    let mk = |imp: KernelImpl| {
        Engine::new(
            runtime(),
            EngineConfig { precision: Precision::F32, cpu_fallback: false, kernel: imp, ..Default::default() },
        )
    };
    let mindist = f.vsq().to_vec();
    let cands: Vec<usize> = (0..64).collect();
    let cmat = v.gather(&cands);

    // one engine per impl: device buffers are client-bound, so the same
    // dataset must keep talking to the same runtime
    let eng_p = mk(KernelImpl::Pallas);
    let eng_j = mk(KernelImpl::Jnp);
    let mut ds_p = DeviceDataset::new(v.clone());
    let mut ds_j = DeviceDataset::new(v.clone());
    let g_pallas = eng_p.gains(&mut ds_p, &mindist, &cmat).unwrap();
    let g_jnp = eng_j.gains(&mut ds_j, &mindist, &cmat).unwrap();
    for i in 0..g_pallas.len() {
        assert!(close(g_pallas[i], g_jnp[i], 1e-4), "i={i}: {} vs {}", g_pallas[i], g_jnp[i]);
    }

    let sets: Vec<Vec<usize>> = vec![vec![3, 14, 150], vec![599], (0..12).collect()];
    let refs: Vec<&[usize]> = sets.iter().map(|s| s.as_slice()).collect();
    let e_pallas = eng_p.eval_sets(&mut ds_p, &refs).unwrap();
    let e_jnp = eng_j.eval_sets(&mut ds_j, &refs).unwrap();
    let cpu = f.eval_sets_st(&refs);
    for i in 0..cpu.len() {
        assert!(close(e_pallas[i], cpu[i], 1e-4), "pallas set {i}");
        assert!(close(e_jnp[i], cpu[i], 1e-4), "jnp set {i}");
    }
}

#[test]
fn ground_buffers_cached_across_calls() {
    if !e2e_enabled("ground_buffers_cached_across_calls") {
        return;
    }
    let mut rng = Rng::new(10);
    let v = Matrix::random_normal(200, 100, &mut rng);
    let eng = engine(Precision::F32);
    let mut ds = DeviceDataset::new(v.clone());
    let mindist = ds.vsq().to_vec();
    let cands = v.gather(&[0, 1]);
    eng.gains(&mut ds, &mindist, &cands).unwrap();
    let uploads_after_first = ds.upload_bytes;
    eng.gains(&mut ds, &mindist, &cands).unwrap();
    assert_eq!(ds.upload_bytes, uploads_after_first, "ground set re-uploaded");
    assert_eq!(ds.bucket_count(), 1);
}
