//! Cross-module integration: coordinator over the XLA engine, config →
//! service wiring, CLI spec, snapshots — the paths the launcher uses.
//!
//! The XLA-backed tests are gated on `RUN_E2E=1` (they need the real
//! `xla` crate + `make artifacts`; the offline stub cannot serve them).
//! Ungated they print a skip line instead of hiding behind `#[ignore]`.

use ebc::cli;
use ebc::util::testing::e2e_enabled;
use ebc::config::parse::ConfigDoc;
use ebc::config::schema::ServiceConfig;
use ebc::coordinator::{snapshot, Coordinator, OracleFactory, RouteResult, SimulatedFleet};
use ebc::engine::{Engine, EngineConfig, OracleSpec, Precision, XlaOracle};
use ebc::imm::{Part, ProcessState};
use ebc::linalg::Matrix;
use ebc::runtime::Runtime;
use ebc::submodular::{CpuOracle, Oracle};
use ebc::util::json::Json;

fn xla_factory(p: Precision) -> OracleFactory {
    let rt = Runtime::discover().expect("make artifacts first");
    let engine = Engine::new(rt, EngineConfig { precision: p, cpu_fallback: true, ..Default::default() });
    Box::new(move |m: ebc::linalg::SharedMatrix, spec: &OracleSpec| {
        let mut engine = engine.clone();
        if let Some(plan) = &spec.plan {
            engine.set_plan(std::sync::Arc::clone(plan));
        }
        Box::new(XlaOracle::from_shared(engine, m)) as Box<dyn Oracle>
    })
}

#[test]
fn coordinator_over_xla_engine_summarizes_fleet() {
    if !e2e_enabled("coordinator_over_xla_engine_summarizes_fleet") {
        return;
    }
    let mut cfg = ServiceConfig::default();
    cfg.summary.k = 3;
    cfg.summary.refresh_every = 100;
    cfg.summary.window = 300;
    cfg.coordinator.queue_capacity = 4096;
    let c = Coordinator::new(cfg, xla_factory(Precision::F32));
    let mut fleet = SimulatedFleet::new(
        &[
            ("imm-a", Part::Cover, ProcessState::Stable),
            ("imm-b", Part::Plate, ProcessState::Regrind),
        ],
        100, // pads into the d=128 bucket
        42,
    );
    let n = c.run_stream(&mut fleet);
    assert_eq!(n, 2000);
    for m in ["imm-a", "imm-b"] {
        match c.query(m) {
            RouteResult::Summary(s) => {
                assert_eq!(s.representative_seqs.len(), 3);
                assert!(s.f_value > 0.0, "{m}: f={}", s.f_value);
            }
            other => panic!("{m}: {other:?}"),
        }
    }
    assert!(c.metrics.refreshes.get() >= 2);
}

#[test]
fn xla_and_cpu_coordinators_agree_on_representatives() {
    if !e2e_enabled("xla_and_cpu_coordinators_agree_on_representatives") {
        return;
    }
    let mk_cfg = || {
        let mut cfg = ServiceConfig::default();
        cfg.summary.k = 4;
        cfg.summary.refresh_every = 1000;
        cfg.summary.window = 400;
        cfg.coordinator.queue_capacity = 4096;
        cfg
    };
    let cpu_factory: OracleFactory = Box::new(|m: ebc::linalg::SharedMatrix, _: &OracleSpec| {
        Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
    });

    let run = |factory: OracleFactory| {
        let c = Coordinator::new(mk_cfg(), factory);
        let mut fleet =
            SimulatedFleet::new(&[("m", Part::Cover, ProcessState::StartUp)], 100, 7);
        c.run_stream(&mut fleet);
        c.refresh("m");
        match c.query("m") {
            RouteResult::Summary(s) => s.representative_seqs,
            other => panic!("{other:?}"),
        }
    };
    let reps_cpu = run(cpu_factory);
    let reps_xla = run(xla_factory(Precision::F32));
    assert_eq!(reps_cpu, reps_xla);
}

#[test]
fn service_config_file_to_coordinator() {
    let doc = ConfigDoc::parse(
        r#"
name = "plant-x"
[engine]
precision = "f32"
[summary]
k = 2
algorithm = "lazy_greedy"
refresh_every = 10
window = 50
[coordinator]
queue_capacity = 64
ingest_batch = 8
"#,
    )
    .unwrap();
    let cfg = ServiceConfig::from_doc(&doc).unwrap();
    let factory: OracleFactory = Box::new(|m: ebc::linalg::SharedMatrix, _: &OracleSpec| {
        Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
    });
    let c = Coordinator::new(cfg, factory);
    let mut fleet = SimulatedFleet::new(&[("p", Part::Plate, ProcessState::Stable)], 24, 9);
    c.run_stream(&mut fleet);
    match c.query("p") {
        RouteResult::Summary(s) => assert_eq!(s.representative_seqs.len(), 2),
        other => panic!("{other:?}"),
    }
    // snapshot is valid JSON with the configured service name
    let snap = snapshot::snapshot(&c);
    let parsed = Json::parse(&snap.dump()).unwrap();
    assert_eq!(parsed.get("service").unwrap().as_str(), Some("plant-x"));
}

#[test]
fn traced_sharded_request_spans_every_layer() {
    use ebc::api::{DatasetRef, Service, ShardSpec, SummarizeRequest};
    let service = Service::from_backend("cpu").unwrap();
    let req = SummarizeRequest::new(DatasetRef::synthetic(240, 12, 11), 4)
        .sharded(ShardSpec::new(2).transport("loopback"))
        .trace(true);
    let res = service.summarize(&req).unwrap();
    let spans = res.provenance.trace.as_ref().expect("trace requested");
    let names: Vec<&str> = spans.iter().map(|s| s.name).collect();
    // one tree covering api -> shard -> transport -> wire -> kernel
    for want in [
        "api.execute",
        "shard.partition",
        "shard.stage1",
        "shard.merge",
        "transport.job",
        "wire.encode",
        "wire.decode",
        "kernel.gains",
    ] {
        assert!(names.contains(&want), "missing span '{want}' in {names:?}");
    }
    // the root is api.execute and every other span descends from it
    let root = spans.iter().find(|s| s.name == "api.execute").unwrap();
    let ids: std::collections::HashSet<u64> = spans.iter().map(|s| s.id).collect();
    for s in spans.iter() {
        if s.id != root.id {
            assert!(ids.contains(&s.parent), "span {} detached from tree", s.name);
        }
    }
    // an untraced request leaves provenance.trace empty
    let quiet = SummarizeRequest::new(DatasetRef::synthetic(240, 12, 11), 4);
    assert!(service.summarize(&quiet).unwrap().provenance.trace.is_none());
}

#[test]
fn cli_spec_covers_all_subcommands() {
    // mirror of the launcher's spec: parse representative command lines
    let spec = cli::AppSpec {
        name: "t",
        about: "t",
        commands: vec![
            cli::CommandSpec {
                name: "summarize",
                help: "",
                flags: vec![
                    cli::opt("n", "", "1000"),
                    cli::opt("backend", "", "xla"),
                ],
            },
            cli::CommandSpec {
                name: "casestudy",
                help: "",
                flags: vec![cli::flag("table2", ""), cli::opt("k", "", "5")],
            },
        ],
    };
    let args: Vec<String> = ["summarize", "--n", "123", "--backend", "cpu"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let (cmd, m) = spec.parse(&args).unwrap();
    assert_eq!(cmd, "summarize");
    assert_eq!(m.usize("n").unwrap(), 123);
    assert_eq!(m.str("backend").unwrap(), "cpu");

    let args: Vec<String> = ["casestudy", "--table2"].iter().map(|s| s.to_string()).collect();
    let (_, m) = spec.parse(&args).unwrap();
    assert!(m.has("table2"));
    assert_eq!(m.usize("k").unwrap(), 5);
}

#[test]
fn bf16_coordinator_close_to_f32() {
    if !e2e_enabled("bf16_coordinator_close_to_f32") {
        return;
    }
    let mk_cfg = || {
        let mut cfg = ServiceConfig::default();
        cfg.summary.k = 3;
        cfg.summary.refresh_every = 1000;
        cfg.summary.window = 200;
        cfg.coordinator.queue_capacity = 2048;
        cfg
    };
    let run = |p: Precision| {
        let c = Coordinator::new(mk_cfg(), xla_factory(p));
        let mut fleet =
            SimulatedFleet::new(&[("m", Part::Cover, ProcessState::Regrind)], 64, 3);
        c.run_stream(&mut fleet);
        c.refresh("m");
        match c.query("m") {
            RouteResult::Summary(s) => s.f_value,
            other => panic!("{other:?}"),
        }
    };
    let f32v = run(Precision::F32);
    let bf16v = run(Precision::Bf16);
    let rel = (f32v - bf16v).abs() / f32v.max(1e-9);
    assert!(rel < 0.05, "f32 {f32v} vs bf16 {bf16v} (rel {rel})");
}

// ------------------------------------------------- failure injection

#[test]
fn missing_hlo_file_is_an_error_not_a_panic() {
    if !e2e_enabled("missing_hlo_file_is_an_error_not_a_panic") {
        return;
    }
    use ebc::runtime::{ArtifactEntry, ArtifactKind, LoadedGraph};
    let rt = Runtime::discover().expect("make artifacts first");
    let entry = ArtifactEntry {
        name: "missing".into(),
        file: std::path::PathBuf::from("/nonexistent/x.hlo.txt"),
        kind: ArtifactKind::Gains,
        imp: ebc::runtime::artifact::KernelImpl::Jnp,
        precision: ebc::runtime::artifact::Precision::F32,
        n: 8,
        d: 8,
        c: 8,
        l: 0,
        k: 0,
        inputs: vec!["v".into()],
        vmem_bytes: 0,
        mxu_flops: 0.0,
        grid_programs: 0,
    };
    assert!(LoadedGraph::compile(rt.client(), &entry).is_err());
}

#[test]
fn corrupt_hlo_text_is_an_error() {
    if !e2e_enabled("corrupt_hlo_text_is_an_error") {
        return;
    }
    use ebc::runtime::{ArtifactEntry, ArtifactKind, LoadedGraph};
    let rt = Runtime::discover().expect("make artifacts first");
    let dir = std::env::temp_dir().join("ebc_corrupt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bad.hlo.txt");
    std::fs::write(&path, "HloModule utterly % broken {{{").unwrap();
    let entry = ArtifactEntry {
        name: "bad".into(),
        file: path,
        kind: ArtifactKind::Update,
        imp: ebc::runtime::artifact::KernelImpl::Jnp,
        precision: ebc::runtime::artifact::Precision::F32,
        n: 8,
        d: 8,
        c: 0,
        l: 0,
        k: 0,
        inputs: vec!["v".into()],
        vmem_bytes: 0,
        mxu_flops: 0.0,
        grid_programs: 0,
    };
    assert!(LoadedGraph::compile(rt.client(), &entry).is_err());
}

#[test]
fn corrupt_manifest_rejected() {
    use ebc::runtime::Manifest;
    let dir = std::env::temp_dir().join("ebc_manifest_test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"version": 1}"#).unwrap();
    assert!(Manifest::load(&dir).is_err()); // entries missing
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"version": 1, "entries": [{"name": "x"}]}"#,
    )
    .unwrap();
    assert!(Manifest::load(&dir).is_err()); // fields missing
}

#[test]
fn engine_chunks_oversized_candidate_batches() {
    if !e2e_enabled("engine_chunks_oversized_candidate_batches") {
        return;
    }
    use ebc::engine::DeviceDataset;
    use ebc::submodular::EbcFunction;
    use ebc::util::rng::Rng;
    let mut rng = Rng::new(77);
    let v = ebc::linalg::Matrix::random_normal(512, 100, &mut rng);
    let f = EbcFunction::new(v.clone());
    let rt = Runtime::discover().expect("make artifacts first");
    let eng = Engine::new(rt, EngineConfig { precision: Precision::F32, cpu_fallback: false, ..Default::default() });
    let mut ds = DeviceDataset::new(v.clone());
    let mindist = f.vsq().to_vec();
    // 3000 candidates exceeds every C bucket (max 1024) -> chunked
    let cands: Vec<usize> = (0..512).cycle().take(3000).collect();
    let got = eng.gains(&mut ds, &mindist, &v.gather(&cands)).unwrap();
    assert_eq!(got.len(), 3000);
    let want = f.gains(&mindist, &cands);
    for i in (0..3000).step_by(371) {
        assert!(
            (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
            "i={i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn single_row_dataset_works() {
    if !e2e_enabled("single_row_dataset_works") {
        return;
    }
    use ebc::submodular::Oracle as _;
    let v = Matrix::from_rows(&[&[3.0f32; 100]]);
    let rt = Runtime::discover().expect("make artifacts first");
    let eng = Engine::new(rt, EngineConfig::default());
    let mut o = XlaOracle::new(eng, v);
    let g = o.gains(&o.vsq().to_vec(), &[0]);
    // singleton gain of the only point = f({v0}) = mean(vsq) = |v0|^2
    assert!((g[0] - 900.0).abs() < 1.0, "{}", g[0]);
}

#[test]
fn artifacts_inventory_complete() {
    if !e2e_enabled("artifacts_inventory_complete") {
        return;
    }
    let rt = Runtime::discover().expect("make artifacts first");
    let man = rt.manifest();
    // both precisions for every kind
    for kind in ["gains", "update", "eval_multi"] {
        for dt in ["f32", "bf16"] {
            assert!(
                man.entries
                    .iter()
                    .any(|e| e.kind.as_str() == kind && e.precision.as_str() == dt),
                "missing {kind}/{dt}"
            );
        }
    }
    // the case-study bucket (d=3524 pads to 3584) must exist for both impls
    use ebc::runtime::artifact::{KernelImpl, Precision as P};
    let jnp = man.pick_gains(1000, 3524, 256, P::F32, KernelImpl::Jnp).unwrap();
    assert_eq!(jnp.imp, KernelImpl::Jnp);
    let pal = man.pick_gains(1000, 3524, 256, P::F32, KernelImpl::Pallas).unwrap();
    assert_eq!(pal.imp, KernelImpl::Pallas);
}
