//! Decoder torture: seeded mutational fuzzing of the wire format,
//! in-tree so it runs under plain `cargo test` on every CI pass.
//!
//! The deeper harness is the cargo-fuzz target in `fuzz/` (coverage
//! guided, unbounded corpus); this file is its deterministic little
//! sibling — a few thousand seeded mutations of valid frames plus raw
//! garbage, pushed through every decoder under `catch_unwind`. The
//! contract under test: **a hostile byte string is always a typed
//! [`WireError`], never a panic** — and a mutation that slips through
//! to `Ok` is fine only because the decoders promise typed rejection,
//! not bit-exact detection (CRC-resealed mutations are legal frames).
//!
//! Every failure message carries the case seed, so a red run
//! reproduces exactly.

use ebc::engine::{KernelImpl, Precision};
use ebc::linalg::{CpuKernel, Matrix};
use ebc::shard::wire::{
    crc32, decode_goodbye, decode_heartbeat, decode_hello, decode_job, decode_request,
    decode_result, encode_goodbye, encode_heartbeat, encode_hello, encode_job, encode_request,
    encode_result, frame_kind, HEADER_LEN, TRAILER_LEN,
};
use ebc::shard::{
    ShardJobMsg, ShardResultMsg, WireDataset, WireGoodbye, WireHeartbeat, WireHello, WireRequest,
    WireShardSpec,
};
use ebc::util::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// One valid frame of every kind — the mutation corpus.
fn corpus() -> Vec<(&'static str, Vec<u8>)> {
    let mut rng = Rng::new(0x70A7);
    let job = ShardJobMsg {
        shard: 1,
        k: 2,
        batch: 32,
        optimizer: "greedy".into(),
        payload: Precision::F32,
        precision: Precision::F32,
        cpu_kernel: CpuKernel::Scalar,
        kernel: KernelImpl::Jnp,
        threads: Some(2),
        plan: None,
        ground_ids: vec![3, 1, 4, 1, 5],
        data: Matrix::random_normal(5, 3, &mut rng),
    };
    let result = ShardResultMsg {
        shard: 1,
        size: 5,
        indices: vec![4, 0],
        f_trajectory: vec![0.5, 0.9],
        f_final: 0.9,
        wall_seconds: 0.01,
        oracle_calls: 10,
        oracle_work: 50,
    };
    let request = WireRequest {
        k: 3,
        batch: 64,
        optimizer: "greedy".into(),
        precision: Precision::F32,
        cpu_kernel: CpuKernel::Blocked,
        threads: 0,
        seed: 7,
        with_baseline: false,
        shard: Some(WireShardSpec {
            partitions: 4,
            partitioner: "hash".into(),
            per_shard_k: 0,
            threads: 0,
            transport: "inproc".into(),
            replicas: 1,
            plan: false,
            cores: 0,
        }),
        dataset: WireDataset::Synthetic { n: 16, d: 4, seed: 11 },
    };
    vec![
        ("job", encode_job(&job)),
        ("result", encode_result(&result)),
        ("request", encode_request(&request)),
        ("hello", encode_hello(&WireHello { id: "torture".into(), capacity: 3 })),
        ("heartbeat", encode_heartbeat(&WireHeartbeat { id: "torture".into(), seq: 99 })),
        (
            "goodbye",
            encode_goodbye(&WireGoodbye {
                id: "torture".into(),
                drain: false,
                detail: "injected".into(),
            }),
        ),
    ]
}

/// Run every decoder over `frame`; the only acceptable outcomes are
/// `Ok` and a typed `WireError` — a panic fails the whole battery.
fn battery(frame: &[u8], what: &str) {
    let checks: [(&str, &dyn Fn(&[u8])); 7] = [
        ("frame_kind", &|f| {
            let _ = frame_kind(f);
        }),
        ("decode_job", &|f| {
            let _ = decode_job(f);
        }),
        ("decode_result", &|f| {
            let _ = decode_result(f);
        }),
        ("decode_request", &|f| {
            let _ = decode_request(f);
        }),
        ("decode_hello", &|f| {
            let _ = decode_hello(f);
        }),
        ("decode_heartbeat", &|f| {
            let _ = decode_heartbeat(f);
        }),
        ("decode_goodbye", &|f| {
            let _ = decode_goodbye(f);
        }),
    ];
    for (name, run) in checks {
        let outcome = catch_unwind(AssertUnwindSafe(|| run(frame)));
        assert!(outcome.is_ok(), "{name} panicked on {what} ({} bytes)", frame.len());
    }
}

/// Recompute the trailer CRC so only post-checksum validation can
/// reject the frame.
fn reseal(frame: &mut Vec<u8>) {
    if frame.len() < TRAILER_LEN {
        return;
    }
    let body = frame.len() - TRAILER_LEN;
    let crc = crc32(&frame[..body]);
    frame[body..].copy_from_slice(&crc.to_le_bytes());
}

/// Apply one seeded mutation; returns a label for failure reports.
fn mutate(rng: &mut Rng, frame: &mut Vec<u8>, donor: &[u8]) -> &'static str {
    match rng.below(8) {
        0 => {
            if !frame.is_empty() {
                let i = rng.below(frame.len());
                frame[i] ^= 1 << rng.below(8);
            }
            "bit flip"
        }
        1 => {
            if !frame.is_empty() {
                let i = rng.below(frame.len());
                frame[i] = rng.below(256) as u8;
            }
            "byte overwrite"
        }
        2 => {
            frame.truncate(rng.below(frame.len() + 1));
            "truncate"
        }
        3 => {
            let extra = rng.below(32) + 1;
            for _ in 0..extra {
                frame.push(rng.below(256) as u8);
            }
            "append garbage"
        }
        4 => {
            // splice: head of this frame, tail of a donor frame
            let cut = rng.below(frame.len() + 1);
            let graft = rng.below(donor.len() + 1);
            frame.truncate(cut);
            frame.extend_from_slice(&donor[graft..]);
            "splice"
        }
        5 => {
            // hostile declared length (header bytes 8..12)
            if frame.len() >= HEADER_LEN {
                let lie = (rng.below(u32::MAX as usize)) as u32;
                frame[8..12].copy_from_slice(&lie.to_le_bytes());
            }
            "length tamper"
        }
        6 => {
            // flip a payload bit, then make the CRC agree: the decoder
            // must survive on structural validation alone
            if frame.len() > HEADER_LEN + TRAILER_LEN {
                let span = frame.len() - HEADER_LEN - TRAILER_LEN;
                let i = HEADER_LEN + rng.below(span);
                frame[i] ^= 1 << rng.below(8);
                reseal(frame);
            }
            "resealed payload flip"
        }
        _ => {
            // length tamper with an agreeing CRC
            if frame.len() >= HEADER_LEN + TRAILER_LEN {
                let lie = (rng.below(1 << 20)) as u32;
                frame[8..12].copy_from_slice(&lie.to_le_bytes());
                reseal(frame);
            }
            "resealed length tamper"
        }
    }
}

#[test]
fn pristine_corpus_decodes_cleanly() {
    for (kind, frame) in corpus() {
        assert!(frame_kind(&frame).is_ok(), "{kind}: pristine frame rejected");
        battery(&frame, kind);
    }
}

#[test]
fn seeded_mutations_never_panic_any_decoder() {
    const CASES_PER_FRAME: usize = 600;
    let corpus = corpus();
    for (ci, (kind, frame)) in corpus.iter().enumerate() {
        let donor = &corpus[(ci + 1) % corpus.len()].1;
        for case in 0..CASES_PER_FRAME {
            let seed = 0xC0FFEE ^ ((ci as u64) << 32) ^ case as u64;
            let mut rng = Rng::new(seed);
            let mut mutant = frame.clone();
            // one to three stacked mutations per case
            let stack = 1 + rng.below(3);
            let mut last = "";
            for _ in 0..stack {
                last = mutate(&mut rng, &mut mutant, donor);
            }
            battery(&mutant, &format!("{kind} seed {seed:#x} last mutation '{last}'"));
        }
    }
}

#[test]
fn raw_garbage_never_panics_any_decoder() {
    let mut rng = Rng::new(0xBAD5EED);
    for case in 0..800 {
        let len = rng.below(192);
        let mut buf: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        battery(&buf, &format!("garbage case {case}"));
        // garbage behind a valid magic header prefix digs deeper
        if buf.len() >= 4 {
            buf[..4].copy_from_slice(b"EBCW");
            battery(&buf, &format!("magic-prefixed garbage case {case}"));
        }
    }
}

#[test]
fn every_truncation_of_every_frame_is_typed() {
    for (kind, frame) in corpus() {
        for cut in 0..frame.len() {
            let slice = &frame[..cut];
            battery(slice, &format!("{kind} truncated to {cut}"));
            assert!(
                frame_kind(slice).is_err(),
                "{kind}: truncation to {cut} of {} still classified",
                frame.len()
            );
        }
    }
}
