//! Deterministic concurrency soak for the production daemon
//! (`ebc::daemon`): producers hammer `offer()` while query clients read
//! and probe jobs occupy workers, then a graceful drain must account
//! for every record.
//!
//! The accounting invariant under test (daemon module docs): a record
//! offered is either *evicted under backpressure* (counted, observable)
//! or *folded into its machine's window* (counted) — never silently
//! lost, including across the drain. Seeds are fixed throughout; the
//! schedule is non-deterministic but every asserted invariant must hold
//! on all schedules.

use ebc::api::Service;
use ebc::config::schema::ServiceConfig;
use ebc::coordinator::{CycleRecord, RouteResult, FLEET_QUERY};
use ebc::daemon::Daemon;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const DIM: usize = 6;

fn soak_cfg() -> ServiceConfig {
    let mut cfg = ServiceConfig::default();
    cfg.name = "soak".into();
    cfg.summary.k = 2;
    cfg.summary.refresh_every = 20;
    cfg.summary.window = 64;
    cfg.coordinator.queue_capacity = 512;
    cfg.coordinator.ingest_batch = 16;
    cfg.daemon.workers = 3;
    cfg.daemon.tick_ms = 2;
    cfg.daemon.refresh_ticks = 3;
    cfg.daemon.fleet_ticks = 10;
    cfg.daemon.job_capacity = 64;
    cfg.daemon.backoff_ms = 2;
    cfg
}

fn rec(machine: String, seq: u64) -> CycleRecord {
    // deterministic, machine-dependent curve so summaries are non-trivial
    let base = machine.len() as f32;
    CycleRecord {
        machine,
        seq,
        values: (0..DIM).map(|j| base + (seq as f32) * 0.01 + j as f32).collect(),
    }
}

#[test]
fn soak_no_lost_records_and_monotone_windows() {
    const PRODUCERS: usize = 4;
    const MACHINES_PER: usize = 2;
    const RECORDS: u64 = 400;
    const QUERIERS: usize = 2;

    let daemon = Arc::new(Daemon::start(Service::cpu().coordinator(soak_cfg())).unwrap());
    let offered = Arc::new(AtomicU64::new(0));
    let done = Arc::new(AtomicBool::new(false));

    // probes occupy workers early so offers race real contention
    for _ in 0..3 {
        daemon.probe(30);
    }

    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let d = Arc::clone(&daemon);
            let offered = Arc::clone(&offered);
            std::thread::spawn(move || {
                // each producer owns its machines: per-machine seqs are
                // strictly increasing at the source by construction
                for s in 0..RECORDS {
                    for m in 0..MACHINES_PER {
                        let name = format!("soak-p{p}-m{m}");
                        assert!(
                            d.offer(rec(name, s)).is_some(),
                            "offer refused before drain"
                        );
                        offered.fetch_add(1, Ordering::SeqCst);
                    }
                    if s % 64 == 0 {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
            })
        })
        .collect();

    let queriers: Vec<_> = (0..QUERIERS)
        .map(|q| {
            let d = Arc::clone(&daemon);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut summaries = 0u64;
                while !done.load(Ordering::SeqCst) {
                    let name = format!("soak-p{}-m{}", q % PRODUCERS, q % MACHINES_PER);
                    match d.query(&name) {
                        RouteResult::Summary(_) => summaries += 1,
                        // machine not folded yet / no summary yet: fine
                        RouteResult::NotReady { .. } | RouteResult::UnknownMachine { .. } => {}
                        other => panic!("unexpected route for {name}: {other:?}"),
                    }
                    match d.query(FLEET_QUERY) {
                        RouteResult::Fleet(_) | RouteResult::NotReady { .. } => {}
                        other => panic!("unexpected fleet route: {other:?}"),
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
                summaries
            })
        })
        .collect();

    for p in producers {
        p.join().unwrap();
    }
    done.store(true, Ordering::SeqCst);
    let mut total_summaries = 0;
    for q in queriers {
        total_summaries += q.join().unwrap();
    }

    let daemon = Arc::try_unwrap(daemon).ok().expect("all clones joined");
    let coord = Arc::clone(daemon.coordinator());
    let report = daemon.drain(Duration::from_secs(30));
    assert!(report.drained, "soak failed to drain: {report:?}");
    assert_eq!(report.queue_len, 0);

    // accounting: every offer was admitted into the bounded queue, and
    // after the drain each admitted record was either folded into its
    // machine's window or evicted under backpressure — nothing lost.
    // (malformed is impossible here: every record has dim DIM)
    let offered = offered.load(Ordering::SeqCst);
    let qs = coord.queue_stats();
    assert_eq!(qs.accepted, offered, "offers not all admitted");
    let folded: u64 = coord.with_machines(|ms| ms.values().map(|m| m.total_ingested).sum());
    assert_eq!(coord.metrics.malformed.get(), 0);
    assert_eq!(
        folded + qs.evicted,
        offered,
        "records lost: folded={folded} evicted={} offered={offered}",
        qs.evicted
    );
    assert_eq!(folded, coord.metrics.ingested.get());

    // per-machine windows kept source order: seqs strictly increasing
    coord.with_machines(|ms| {
        assert_eq!(ms.len(), PRODUCERS * MACHINES_PER);
        for (name, m) in ms {
            let (_, seqs) =
                m.window_matrix().unwrap_or_else(|| panic!("empty window for {name}"));
            for w in seqs.windows(2) {
                assert!(w[0] < w[1], "{name}: window seqs out of order: {seqs:?}");
            }
        }
    });
    // queriers observed a live system (summaries may lag producers, but
    // the counter proves reads and writes truly interleaved)
    println!("soak: {offered} offered, {folded} folded, {total_summaries} summary reads");
}

#[test]
fn scheduler_refreshes_without_manual_ticks() {
    // no explicit tick()/refresh() calls anywhere: offers alone must
    // produce a summary via the scheduler + worker pipeline
    let daemon = Daemon::start(Service::cpu().coordinator(soak_cfg())).unwrap();
    for s in 0..50u64 {
        daemon.offer(rec("sched-m1".into(), s));
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if matches!(daemon.query("sched-m1"), RouteResult::Summary(_)) {
            break;
        }
        assert!(Instant::now() < deadline, "scheduler never refreshed sched-m1");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(daemon.metrics().ticks.get() > 0);
    let report = daemon.drain(Duration::from_secs(5));
    assert!(report.drained);
}
