//! Property-based tests (via the in-tree mini-framework,
//! `ebc::util::proptest`) over the mathematical invariants the paper
//! relies on and the coordinator's state machine.

use ebc::coordinator::backpressure::BoundedQueue;
use ebc::coordinator::{Coordinator, CycleRecord, RouteResult};
use ebc::config::schema::ServiceConfig;
use ebc::engine::{
    DeviceDataset, EngineConfig, KernelImpl, OracleSpec, PlanRequest, Precision, ShardPlan,
};
use ebc::linalg::gemm::gemm_nt;
use ebc::linalg::{CpuKernel, Matrix, SharedMatrix};
use ebc::optim::{exhaustive_best, Greedy, LazyGreedy, Optimizer, SieveStreaming};
use ebc::optim::greedy_over_candidates;
use ebc::runtime::Manifest;
use ebc::shard::wire::{decode_job, decode_result, encode_job, encode_result};
use ebc::shard::{
    build_partitioner, spawn_replica, validate_partition, LoopbackReplicaTransport, NetOptions,
    Partitioner, ShardJobMsg, ShardResultMsg, ShardTransport, ShardedSummarizer,
    TcpReplicaTransport, WirePlan, PARTITIONERS,
};
use ebc::submodular::{fold_mindist, CpuOracle, EbcFunction, Oracle};
use ebc::util::proptest::{arb_dataset, arb_subset, forall, Config};
use ebc::util::rng::Rng;
use std::sync::Arc;

fn cfg() -> Config {
    Config::default()
}

// ---------------------------------------------------------------- EBC math

#[test]
fn prop_ebc_is_monotone() {
    forall(
        "EBC monotone: A ⊆ B ⇒ f(A) <= f(B)",
        &cfg(),
        |rng| {
            let (n, d, data) = arb_dataset(rng, 25, 8, 2.0);
            let a = arb_subset(rng, n, 4);
            let mut b = a.clone();
            for e in arb_subset(rng, n, 4) {
                if !b.contains(&e) {
                    b.push(e);
                }
            }
            (n, d, data, a, b)
        },
        |(n, d, data, a, b)| {
            let f = EbcFunction::new(Matrix::from_vec(*n, *d, data.clone()));
            let fa = f.eval(a);
            let fb = f.eval(b);
            if fb >= fa - 1e-5 {
                Ok(())
            } else {
                Err(format!("f(A)={fa} > f(B)={fb}"))
            }
        },
    );
}

#[test]
fn prop_ebc_is_submodular() {
    forall(
        "EBC diminishing returns: Δ(e|A) >= Δ(e|B) for A ⊆ B",
        &cfg(),
        |rng| {
            let (n, d, data) = arb_dataset(rng, 20, 6, 2.0);
            let a = arb_subset(rng, n, 3);
            let mut b = a.clone();
            for x in arb_subset(rng, n, 4) {
                if !b.contains(&x) {
                    b.push(x);
                }
            }
            let e = rng.below(n);
            (n, d, data, a, b, e)
        },
        |(n, d, data, a, b, e)| {
            if b.contains(e) {
                return Ok(());
            }
            let f = EbcFunction::new(Matrix::from_vec(*n, *d, data.clone()));
            let ga = f.eval(&[a.clone(), vec![*e]].concat()) - f.eval(a);
            let gb = f.eval(&[b.clone(), vec![*e]].concat()) - f.eval(b);
            if ga >= gb - 1e-4 {
                Ok(())
            } else {
                Err(format!("Δ(e|A)={ga} < Δ(e|B)={gb}"))
            }
        },
    );
}

#[test]
fn prop_ebc_nonnegative_and_empty_zero() {
    forall(
        "EBC: f(∅)=0 and f(S) >= 0",
        &cfg(),
        |rng| {
            let (n, d, data) = arb_dataset(rng, 30, 6, 2.0);
            let s = arb_subset(rng, n, 6);
            (n, d, data, s)
        },
        |(n, d, data, s)| {
            let f = EbcFunction::new(Matrix::from_vec(*n, *d, data.clone()));
            if f.eval(&[]) != 0.0 {
                return Err("f(∅) != 0".into());
            }
            let v = f.eval(s);
            if v >= -1e-6 {
                Ok(())
            } else {
                Err(format!("f(S)={v} < 0"))
            }
        },
    );
}

// ----------------------------------------------------------- optimizers

#[test]
fn prop_greedy_guarantee_vs_exhaustive() {
    let cfg = Config { cases: 12, ..Config::default() };
    forall(
        "greedy >= (1 - 1/e) OPT",
        &cfg,
        |rng| {
            let (n, d, data) = arb_dataset(rng, 11, 4, 2.0);
            let k = 1 + rng.below(3);
            (n, d, data, k)
        },
        |(n, d, data, k)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), *k);
            let (_, opt) = exhaustive_best(&mut CpuOracle::new(v), *k);
            let bound = (1.0 - (-1.0f32).exp()) * opt;
            if g.f_final >= bound - 1e-5 {
                Ok(())
            } else {
                Err(format!("greedy {} < bound {bound} (opt {opt})", g.f_final))
            }
        },
    );
}

#[test]
fn prop_lazy_equals_greedy() {
    forall(
        "lazy greedy f == plain greedy f",
        &Config { cases: 10, ..Config::default() },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 40, 5, 2.0);
            let k = 1 + rng.below(6);
            (n, d, data, k)
        },
        |(n, d, data, k)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), *k);
            let l = LazyGreedy::default().run(&mut CpuOracle::new(v), *k);
            if (g.f_final - l.f_final).abs() <= 1e-4 * (1.0 + g.f_final.abs()) {
                Ok(())
            } else {
                Err(format!("greedy {} vs lazy {}", g.f_final, l.f_final))
            }
        },
    );
}

#[test]
fn prop_sieve_streaming_guarantee() {
    forall(
        "sieve streaming >= ~(1/2 - eps) greedy",
        &Config { cases: 8, ..Config::default() },
        |rng| {
            let (_, d, _) = arb_dataset(rng, 10, 4, 2.0);
            let n = 20 + rng.below(40);
            let data: Vec<f32> = (0..n * d).map(|_| rng.normal() * 2.0).collect();
            (n, d, data, 3usize)
        },
        |(n, d, data, k)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            let g = Greedy::default().run(&mut CpuOracle::new(v.clone()), *k);
            let s = SieveStreaming { epsilon: 0.05 }.run(&mut CpuOracle::new(v), *k);
            // generous slack: the 1/2-eps bound is vs OPT, greedy ≈ OPT
            if s.f_final >= 0.40 * g.f_final - 1e-5 {
                Ok(())
            } else {
                Err(format!("sieve {} << greedy {}", s.f_final, g.f_final))
            }
        },
    );
}

// ---------------------------------------------------------- coordinator

#[test]
fn prop_bounded_queue_never_overflows() {
    forall(
        "queue len <= capacity, accounting consistent",
        &cfg(),
        |rng| {
            let cap = 1 + rng.below(32);
            let ops = 1 + rng.below(200);
            (cap, ops, rng.next_u64())
        },
        |(cap, ops, seed)| {
            let mut rng = Rng::new(*seed);
            let mut q = BoundedQueue::new(*cap);
            let mut popped = 0u64;
            for i in 0..*ops {
                if rng.f32() < 0.7 {
                    q.push(i);
                } else if q.pop().is_some() {
                    popped += 1;
                }
                if q.len() > *cap {
                    return Err(format!("len {} > cap {cap}", q.len()));
                }
            }
            let accounted = q.len() as u64 + popped + q.evicted;
            if accounted == q.accepted {
                Ok(())
            } else {
                Err(format!(
                    "accounting: len {} + popped {popped} + evicted {} != accepted {}",
                    q.len(),
                    q.evicted,
                    q.accepted
                ))
            }
        },
    );
}

#[test]
fn prop_coordinator_summary_within_window() {
    forall(
        "summary representatives always inside the current window",
        &Config { cases: 10, ..Config::default() },
        |rng| {
            let window = 5 + rng.below(20);
            let total = 10 + rng.below(80);
            let d = 2 + rng.below(4);
            (window, total, d, rng.next_u64())
        },
        |(window, total, d, seed)| {
            let mut rng = Rng::new(*seed);
            let mut cfg = ServiceConfig::default();
            cfg.summary.k = 3;
            cfg.summary.refresh_every = 4;
            cfg.summary.window = *window;
            let factory = Box::new(|m: SharedMatrix, _spec: &OracleSpec| {
                Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
            });
            let c = Coordinator::new(cfg, factory);
            for s in 0..*total as u64 {
                let vals: Vec<f32> = (0..*d).map(|_| rng.normal()).collect();
                c.offer(CycleRecord { machine: "m".into(), seq: s, values: vals });
                c.tick();
            }
            while c.queue_len() > 0 {
                c.tick();
            }
            c.refresh("m");
            match c.query("m") {
                RouteResult::Summary(s) => {
                    let lo = (*total as u64).saturating_sub(*window as u64);
                    if s.representative_seqs.iter().all(|&q| q >= lo) {
                        Ok(())
                    } else {
                        Err(format!("reps {:?} below window floor {lo}", s.representative_seqs))
                    }
                }
                other => Err(format!("no summary: {other:?}")),
            }
        },
    );
}

// --------------------------------------------------- CPU MT == CPU ST

#[test]
fn prop_mt_eval_matches_st() {
    forall(
        "MT multi-set eval == ST",
        &Config { cases: 10, ..Config::default() },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 40, 5, 1.5);
            let sets: Vec<Vec<usize>> =
                (0..1 + rng.below(6)).map(|_| arb_subset(rng, n, 5)).collect();
            (n, d, data, sets)
        },
        |(n, d, data, sets)| {
            let f = EbcFunction::new(Matrix::from_vec(*n, *d, data.clone()));
            let refs: Vec<&[usize]> = sets.iter().map(|s| s.as_slice()).collect();
            let st = f.eval_sets_st(&refs);
            let mt = f.eval_sets_mt(&refs, 3);
            for (a, b) in st.iter().zip(&mt) {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("st {a} vs mt {b}"));
                }
            }
            Ok(())
        },
    );
}

// --------------------------------------------- greedy batching invariance

#[test]
fn prop_greedy_batch_invariant() {
    forall(
        "greedy result independent of candidate batch size",
        &Config { cases: 8, ..Config::default() },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 50, 4, 2.0);
            let b1 = 1 + rng.below(16);
            let b2 = 17 + rng.below(64);
            (n, d, data, b1, b2)
        },
        |(n, d, data, b1, b2)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            let r1 = Greedy { batch: *b1 }.run(&mut CpuOracle::new(v.clone()), 5);
            let r2 = Greedy { batch: *b2 }.run(&mut CpuOracle::new(v), 5);
            if r1.indices == r2.indices {
                Ok(())
            } else {
                Err(format!("{:?} vs {:?}", r1.indices, r2.indices))
            }
        },
    );
}

// --------------------------------------------------- shard subsystem

fn sharded_cpu(
    v: &SharedMatrix,
    partitioner: &str,
    shards: usize,
    k: usize,
) -> ebc::shard::ShardedResult {
    let part = build_partitioner(partitioner, 11).expect("known partitioner");
    let greedy = Greedy::default();
    let s = ShardedSummarizer::new(part.as_ref(), &greedy, shards);
    let factory =
        |m: SharedMatrix, _spec: &OracleSpec| Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>;
    s.summarize(v, &factory, k)
}

#[test]
fn prop_partitioners_cover_disjoint_ascending() {
    forall(
        "every partitioner: exact disjoint ascending cover of the ground set",
        &Config { cases: 24, seed: 0x5A4D },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 40, 6, 2.0);
            let shards = 1 + rng.below(6);
            (n, d, data, shards)
        },
        |(n, d, data, shards)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            for name in PARTITIONERS {
                let p = build_partitioner(name, 3).expect("known partitioner");
                let parts = p.partition(&v, *shards);
                validate_partition(&parts, *n, *shards)
                    .map_err(|e| format!("{name}: {e}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_p1_equals_single_node_greedy() {
    // satellite invariant: any partitioner at P = 1 reproduces the
    // single-node greedy selection and value bit for bit
    forall(
        "sharded P=1 == single-node greedy (all partitioners)",
        &Config { cases: 12, seed: 0x51AD },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 30, 5, 2.0);
            let k = 1 + rng.below(5);
            (n, d, data, k)
        },
        |(n, d, data, k)| {
            let v = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let single = Greedy::default().run(&mut CpuOracle::new_shared(Arc::clone(&v)), *k);
            for name in PARTITIONERS {
                let res = sharded_cpu(&v, name, 1, *k);
                if res.merged.indices != single.indices {
                    return Err(format!(
                        "{name}: {:?} != {:?}",
                        res.merged.indices, single.indices
                    ));
                }
                if res.merged.f_final.to_bits() != single.f_final.to_bits() {
                    return Err(format!(
                        "{name}: f {} != {}",
                        res.merged.f_final, single.f_final
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_within_constant_factor_of_opt() {
    // satellite invariant: on tiny instances, any partitioner and
    // P ∈ {1, 2, 4} stay within a constant factor of the exhaustive
    // optimum (greedy alone guarantees 1 − 1/e ≈ 0.63; sharding costs a
    // bounded extra factor — 0.3 leaves deterministic-margin headroom)
    forall(
        "sharded merged f >= 0.3 * OPT (P in {1,2,4}, all partitioners)",
        &Config { cases: 10, seed: 0xC0FA },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 11, 4, 2.0);
            let k = 1 + rng.below(3);
            (n, d, data, k)
        },
        |(n, d, data, k)| {
            let v = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let (_, opt) = exhaustive_best(&mut CpuOracle::new_shared(Arc::clone(&v)), *k);
            for name in PARTITIONERS {
                for shards in [1usize, 2, 4] {
                    let res = sharded_cpu(&v, name, shards, *k);
                    if res.merged.f_final < 0.3 * opt - 1e-6 {
                        return Err(format!(
                            "{name}/P={shards}: merged {} < 0.3 * opt {opt}",
                            res.merged.f_final
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// --------------------------------------------------- shard transport

/// The pre-PR direct path: partition → per-shard greedy (no wire, no
/// transport, plain function calls) → merge. The transported pipeline
/// must reproduce this exactly.
fn direct_two_stage(
    v: &SharedMatrix,
    partitioner: &dyn Partitioner,
    shards: usize,
    k: usize,
) -> (Vec<usize>, f32) {
    let parts: Vec<Vec<usize>> = partitioner
        .partition(v, shards)
        .into_iter()
        .filter(|p| !p.is_empty())
        .collect();
    let greedy = Greedy::default();
    let mut union: Vec<usize> = Vec::new();
    for part in &parts {
        let sub = Arc::new(v.gather(part));
        let mut res = greedy.run(&mut CpuOracle::new_shared(sub), k.min(part.len()));
        for idx in res.indices.iter_mut() {
            *idx = part[*idx];
        }
        union.extend(res.indices);
    }
    union.sort_unstable();
    union.dedup();
    let merged =
        greedy_over_candidates(&mut CpuOracle::new_shared(Arc::clone(v)), &union, k, 1024);
    (merged.indices, merged.f_final)
}

#[test]
fn prop_transport_identity_inproc_loopback_direct() {
    // tentpole invariant: for random matrices and every partitioner,
    // the inproc transport, the loopback transport and the pre-PR
    // direct path select identical exemplars with identical f bits
    forall(
        "inproc == loopback == direct (indices + f bits, all partitioners)",
        &Config { cases: 8, seed: 0x7149 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 40, 5, 2.0);
            let shards = 1 + rng.below(5);
            let k = 1 + rng.below(4);
            let replicas = 1 + rng.below(4);
            (n, d, data, shards, k, replicas)
        },
        |(n, d, data, shards, k, replicas)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let factory = |m: SharedMatrix, _spec: &OracleSpec| {
                Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
            };
            let greedy = Greedy::default();
            for name in PARTITIONERS {
                let part = build_partitioner(name, 11).expect("known partitioner");
                let (want_idx, want_f) = direct_two_stage(&v, part.as_ref(), *shards, *k);
                let lb = LoopbackReplicaTransport::with_replicas(*replicas, 1);
                let transports: [(&str, Option<&dyn ShardTransport>); 2] =
                    [("inproc default", None), ("loopback", Some(&lb))];
                for (label, transport) in transports {
                    let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, *shards);
                    s.transport = transport;
                    let res = s.summarize(&v, &factory, *k);
                    if res.merged.indices != want_idx {
                        return Err(format!(
                            "{name}/{label}: {:?} != direct {want_idx:?}",
                            res.merged.indices
                        ));
                    }
                    if res.merged.f_final.to_bits() != want_f.to_bits() {
                        return Err(format!(
                            "{name}/{label}: f {} != direct {want_f}",
                            res.merged.f_final
                        ));
                    }
                    if res.wire_bytes == 0 {
                        return Err(format!("{name}/{label}: no wire traffic recorded"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_transport_identity_tcp_direct() {
    // tentpole invariant: the socket leg is selection-invisible — a
    // real localhost replica fleet selects identical exemplars (and f
    // bits) to the pre-PR direct path, for every partitioner
    forall(
        "tcp == direct (indices + f bits, all partitioners)",
        &Config { cases: 4, seed: 0x7C9 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 30, 4, 2.0);
            let shards = 1 + rng.below(4);
            let k = 1 + rng.below(3);
            let replicas = 1 + rng.below(2);
            (n, d, data, shards, k, replicas)
        },
        |(n, d, data, shards, k, replicas)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let factory = |m: SharedMatrix, _spec: &OracleSpec| {
                Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
            };
            let servers = (0..*replicas)
                .map(|i| {
                    spawn_replica(
                        "127.0.0.1:0",
                        &format!("prop-replica-{i}"),
                        1,
                        1,
                        &NetOptions::default(),
                        |m: SharedMatrix, _spec: &OracleSpec| {
                            Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
                        },
                    )
                    .map_err(|e| format!("spawn: {e}"))
                })
                .collect::<Result<Vec<_>, String>>()?;
            let tcp = TcpReplicaTransport::new(NetOptions {
                addrs: servers.iter().map(|s| s.addr()).collect(),
                ..NetOptions::default()
            });
            let greedy = Greedy::default();
            for name in PARTITIONERS {
                let part = build_partitioner(name, 11).expect("known partitioner");
                let (want_idx, want_f) = direct_two_stage(&v, part.as_ref(), *shards, *k);
                let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, *shards);
                s.transport = Some(&tcp);
                let res = s.summarize(&v, &factory, *k);
                if res.degraded {
                    return Err(format!("{name}: tcp run degraded to inproc"));
                }
                if res.merged.indices != want_idx {
                    return Err(format!(
                        "{name}: {:?} != direct {want_idx:?}",
                        res.merged.indices
                    ));
                }
                if res.merged.f_final.to_bits() != want_f.to_bits() {
                    return Err(format!(
                        "{name}: f {} != direct {want_f}",
                        res.merged.f_final
                    ));
                }
                if res.wire_bytes == 0 {
                    return Err(format!("{name}: no wire traffic recorded"));
                }
            }
            for s in servers {
                s.stop();
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------ api façade

#[test]
fn prop_api_request_roundtrips_wire_losslessly() {
    // satellite invariant: any builder-made registry request survives
    // the WireRequest codec — f32 inline payloads byte-stable on
    // re-encode, bf16 payloads equal to the demoted matrix
    use ebc::api::{DatasetRef, ShardSpec, SummarizeRequest};
    use ebc::shard::wire::{decode_request, encode_request};
    forall(
        "api request -> WireRequest -> api request is lossless",
        &Config { cases: 24, seed: 0xA4B1 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 20, 5, 2.0);
            let k = 1 + rng.below(n.min(5));
            let alg = ["greedy", "lazy_greedy", "three_sieves"][rng.below(3)];
            let partitioner = PARTITIONERS[rng.below(PARTITIONERS.len())];
            let sharded = rng.below(2) == 1;
            let shards = 1 + rng.below(5);
            let bf16 = rng.below(2) == 1;
            (n, d, data, k, alg, partitioner, sharded, shards, bf16)
        },
        |(n, d, data, k, alg, partitioner, sharded, shards, bf16)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let mut req = SummarizeRequest::new(DatasetRef::Inline(Arc::clone(&v)), *k)
                .optimizer(alg)
                .batch(64)
                .seed(9)
                .with_baseline(*sharded);
            if *sharded {
                req = req.sharded(
                    ShardSpec::new(*shards).partitioner(partitioner).transport("loopback"),
                );
            }
            req.validate().map_err(|e| format!("validate: {e}"))?;

            // f32 payload: lossless and byte-stable
            let wire = req.to_wire(Precision::F32).map_err(|e| e.to_string())?;
            let frame = encode_request(&wire);
            let back = decode_request(&frame).map_err(|e| e.to_string())?;
            let rebuilt = SummarizeRequest::from_wire(&back);
            if rebuilt != req {
                return Err(format!("f32 round trip drifted: {rebuilt:?}"));
            }
            if encode_request(&back) != frame {
                return Err("f32 re-encode not byte-stable".into());
            }

            if *bf16 {
                // bf16 payload: the rebuilt dataset equals the demoted one
                let wire = req.to_wire(Precision::Bf16).map_err(|e| e.to_string())?;
                let frame = encode_request(&wire);
                let back = decode_request(&frame).map_err(|e| e.to_string())?;
                let rebuilt = SummarizeRequest::from_wire(&back);
                let got = match &rebuilt.dataset {
                    DatasetRef::Inline(m) => m.data().to_vec(),
                    other => return Err(format!("dataset kind drifted: {other:?}")),
                };
                let want: Vec<f32> = v
                    .data()
                    .iter()
                    .map(|&x| ebc::linalg::gemm::bf16_round(x))
                    .collect();
                if got != want {
                    return Err("bf16 payload != demoted matrix".into());
                }
                if encode_request(&back) != frame {
                    return Err("bf16 re-encode not byte-stable".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_api_path_selection_identical_to_direct_path() {
    // tentpole invariant: a request executed through api::Service
    // selects the identical exemplars (and f bits) as the directly
    // constructed ShardedSummarizer, for every partitioner and both
    // transports
    use ebc::api::{DatasetRef, Service, ShardSpec, SummarizeRequest};
    forall(
        "api::Service::summarize == direct ShardedSummarizer (all partitioners)",
        &Config { cases: 6, seed: 0xFACA },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 40, 5, 2.0);
            let shards = 1 + rng.below(5);
            let k = 1 + rng.below(4);
            (n, d, data, shards, k)
        },
        |(n, d, data, shards, k)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let service = Service::cpu();
            // the direct path mirrors the service's cpu factory knobs
            let factory = |m: SharedMatrix, spec: &OracleSpec| {
                Box::new(ebc::submodular::CpuOracle::with_kernel_shared(
                    m,
                    CpuKernel::Scalar,
                    Precision::F32,
                    spec.threads_or(1),
                )) as Box<dyn Oracle>
            };
            let greedy = Greedy { batch: 1024 };
            for name in PARTITIONERS {
                for transport in ["inproc", "loopback"] {
                    let part = build_partitioner(name, 21).expect("known partitioner");
                    let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, *shards);
                    let lb;
                    if transport == "loopback" {
                        lb = LoopbackReplicaTransport::with_replicas(2, 1);
                        s.transport = Some(&lb);
                    }
                    let direct = s.summarize(&v, &factory, *k);

                    let req = SummarizeRequest::new(DatasetRef::Inline(Arc::clone(&v)), *k)
                        .cpu_kernel(CpuKernel::Scalar)
                        .threads(1)
                        .seed(21)
                        .sharded(
                            ShardSpec::new(*shards)
                                .partitioner(name)
                                .transport(transport)
                                .replicas(2),
                        );
                    let resp = service.summarize(&req).map_err(|e| e.to_string())?;

                    let want: Vec<u64> =
                        direct.merged.indices.iter().map(|&i| i as u64).collect();
                    if resp.exemplars != want {
                        return Err(format!(
                            "{name}/{transport}: api {:?} != direct {want:?}",
                            resp.exemplars
                        ));
                    }
                    if resp.f_final.to_bits() != direct.merged.f_final.to_bits() {
                        return Err(format!(
                            "{name}/{transport}: f {} != {}",
                            resp.f_final, direct.merged.f_final
                        ));
                    }
                    if resp.provenance.transport != Some(transport) {
                        return Err(format!(
                            "{name}: provenance says {:?}",
                            resp.provenance.transport
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_invalid_requests_yield_typed_errors_never_panics() {
    // satellite invariant: malformed requests come back as ApiError —
    // k = 0, k > n, unknown optimizer, and the remote-rebuild contract
    // (custom optimizer over a non-inproc transport)
    use ebc::api::{ApiError, DatasetRef, Service, ShardSpec, SummarizeRequest};
    forall(
        "invalid requests -> typed ApiError",
        &Config { cases: 16, seed: 0xBAD1 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 20, 4, 2.0);
            let shards = 1 + rng.below(4);
            (n, d, data, shards)
        },
        |(n, d, data, shards)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let service = Service::cpu();
            let ds = DatasetRef::Inline(Arc::clone(&v));

            let mut zero_k = SummarizeRequest::new(ds.clone(), 1);
            zero_k.k = 0;
            match service.summarize(&zero_k) {
                Err(ApiError::Invalid { field: "k", .. }) => {}
                other => return Err(format!("k=0: {other:?}")),
            }
            match service.summarize(&SummarizeRequest::new(ds.clone(), n + 1)) {
                Err(ApiError::Invalid { field: "k", .. }) => {}
                other => return Err(format!("k>n: {other:?}")),
            }
            match service.summarize(&SummarizeRequest::new(ds.clone(), 1).optimizer("psychic")) {
                Err(ApiError::UnknownName { field: "optimizer", .. }) => {}
                other => return Err(format!("unknown optimizer: {other:?}")),
            }
            let custom: Arc<dyn ebc::optim::Optimizer> =
                Arc::new(SieveStreaming::default());
            let remote_custom = SummarizeRequest::new(ds.clone(), 1)
                .custom_optimizer(Arc::clone(&custom))
                .sharded(ShardSpec::new(*shards).transport("loopback"));
            match service.summarize(&remote_custom) {
                Err(ApiError::NonRegistryOptimizer { transport }) => {
                    if transport != "loopback" {
                        return Err(format!("wrong transport in error: {transport}"));
                    }
                }
                other => return Err(format!("custom+loopback: {other:?}")),
            }
            // ...while the same custom optimizer runs fine in-process
            let local_custom = SummarizeRequest::new(ds.clone(), 1)
                .custom_optimizer(custom)
                .sharded(ShardSpec::new(*shards));
            service
                .summarize(&local_custom)
                .map_err(|e| format!("custom+inproc should run: {e}"))?;
            Ok(())
        },
    );
}

fn arb_job(rng: &mut ebc::util::rng::Rng, payload: Precision) -> ShardJobMsg {
    let rows = 1 + rng.below(12);
    let cols = 1 + rng.below(6);
    let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 3.0).collect();
    let plan = (rng.below(2) == 1).then(|| {
        let mut req = PlanRequest::new(1 + rng.below(100), cols, 1 + rng.below(8), 3);
        req.cores = 1 + rng.below(16);
        WirePlan::of(&ShardPlan::plan(None, &req))
    });
    ShardJobMsg {
        shard: rng.below(1000) as u32,
        k: (1 + rng.below(6)) as u32,
        batch: (1 + rng.below(2048)) as u32,
        optimizer: ["greedy", "lazy_greedy", "stochastic_greedy"][rng.below(3)].into(),
        payload,
        precision: if rng.below(2) == 1 { Precision::Bf16 } else { Precision::F32 },
        cpu_kernel: [CpuKernel::Scalar, CpuKernel::Blocked, CpuKernel::Simd][rng.below(3)],
        kernel: if rng.below(2) == 1 { KernelImpl::Jnp } else { KernelImpl::Pallas },
        threads: (rng.below(2) == 1).then(|| rng.below(16) as u32),
        plan,
        ground_ids: (0..rows).map(|_| rng.next_u64() >> 16).collect(),
        data: Matrix::from_vec(rows, cols, data),
    }
}

#[test]
fn prop_wire_roundtrip_lossless_f32_and_bf16() {
    // satellite invariant: encode → decode is lossless for f32 payloads
    // and value-preserving (== the demoted matrix, byte-stable on
    // re-encode) for bf16 payloads; result frames are always lossless
    forall(
        "wire encode/decode round trip (f32 lossless, bf16 demoted-lossless)",
        &Config { cases: 32, seed: 0x311E },
        |rng| {
            let f32_job = arb_job(rng, Precision::F32);
            let bf16_job = arb_job(rng, Precision::Bf16);
            let k = 1 + rng.below(5);
            let result = ShardResultMsg {
                shard: rng.below(100) as u32,
                size: (k + rng.below(50)) as u32,
                indices: (0..k).map(|_| rng.next_u64() >> 8).collect(),
                f_trajectory: (0..k).map(|_| rng.f32() * 10.0).collect(),
                f_final: rng.f32() * 10.0,
                wall_seconds: rng.f32() as f64,
                oracle_calls: rng.next_u64() >> 32,
                oracle_work: rng.next_u64() >> 16,
            };
            (f32_job, bf16_job, result)
        },
        |(f32_job, bf16_job, result)| {
            let frame = encode_job(f32_job);
            let back = decode_job(&frame).map_err(|e| e.to_string())?;
            if &back != f32_job {
                return Err(format!("f32 job round trip drifted: {back:?}"));
            }
            if encode_job(&back) != frame {
                return Err("f32 re-encode not byte-stable".into());
            }

            let frame = encode_job(bf16_job);
            let back = decode_job(&frame).map_err(|e| e.to_string())?;
            let want: Vec<f32> = bf16_job
                .data
                .data()
                .iter()
                .map(|&v| ebc::linalg::gemm::bf16_round(v))
                .collect();
            if back.data.data() != &want[..] {
                return Err("bf16 payload != demoted matrix".into());
            }
            if back.ground_ids != bf16_job.ground_ids || back.optimizer != bf16_job.optimizer {
                return Err("bf16 job metadata drifted".into());
            }
            // demotion is idempotent, so the second trip is lossless
            if encode_job(&back) != frame {
                return Err("bf16 re-encode not byte-stable".into());
            }

            let frame = encode_result(result);
            let back = decode_result(&frame).map_err(|e| e.to_string())?;
            if &back != result {
                return Err(format!("result round trip drifted: {back:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_replica_failure_preserves_selection_and_counts_retries() {
    // satellite invariant: killing a replica mid-run re-queues its
    // shards to survivors with an unchanged merged selection, and the
    // transport counts every re-queued shard
    forall(
        "replica death mid-run: selection identical, retries counted",
        &Config { cases: 8, seed: 0xDEAD },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 50, 5, 2.0);
            let shards = 3 + rng.below(5);
            let k = 1 + rng.below(4);
            let replicas = 2 + rng.below(3);
            let survive = rng.below(2); // jobs the victim finishes first
            (n, d, data, shards, k, replicas, survive)
        },
        |(n, d, data, shards, k, replicas, survive)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let factory = |m: SharedMatrix, _spec: &OracleSpec| {
                Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
            };
            let greedy = Greedy::default();
            let part = build_partitioner("round_robin", 0).expect("known partitioner");

            let healthy = LoopbackReplicaTransport::with_replicas(*replicas, 1);
            let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, *shards);
            s.transport = Some(&healthy);
            let want = s.summarize(&v, &factory, *k);

            let chaotic = LoopbackReplicaTransport::with_replicas(*replicas, 1);
            chaotic.fail_after("replica-0", *survive as u64);
            let mut s = ShardedSummarizer::new(part.as_ref(), &greedy, *shards);
            s.transport = Some(&chaotic);
            let got = s.summarize(&v, &factory, *k);

            if got.merged.indices != want.merged.indices {
                return Err(format!(
                    "selection changed: {:?} != {:?}",
                    got.merged.indices, want.merged.indices
                ));
            }
            if got.merged.f_final.to_bits() != want.merged.f_final.to_bits() {
                return Err(format!("f changed: {} != {}", got.merged.f_final, want.merged.f_final));
            }
            // the victim never outlives its failure budget...
            let done = chaotic
                .with_registry(|reg| reg.get("replica-0").map(|r| r.jobs_done).unwrap_or(0));
            if done > *survive as u64 {
                return Err(format!("victim completed {done} > budget {survive}"));
            }
            // ...and every shard it was dealt but could not finish is a
            // counted retry: the capacity-weighted deal hands replica-0
            // ceil(jobs / replicas) shards in round 1
            let first_deal = got.shards_used.div_ceil(*replicas);
            let lost = first_deal.saturating_sub(*survive) as u64;
            if got.shard_retries != lost {
                return Err(format!(
                    "expected {lost} retried shard(s) (dealt {first_deal}, budget {survive}), \
                     transport counted {}",
                    got.shard_retries
                ));
            }
            Ok(())
        },
    );
}

const PLAN_MANIFEST: &str = r#"{
  "version": 1,
  "entries": [
    {"name": "gains_s", "file": "a.hlo.txt", "kind": "gains", "dtype": "f32",
     "n": 64, "d": 16, "c": 32, "l": 0, "k": 0,
     "inputs": ["v","vsq","vmask","mindist","c","cmask"]},
    {"name": "gains_m", "file": "b.hlo.txt", "kind": "gains", "dtype": "f32",
     "n": 256, "d": 32, "c": 128, "l": 0, "k": 0,
     "inputs": ["v","vsq","vmask","mindist","c","cmask"]},
    {"name": "gains_l", "file": "c.hlo.txt", "kind": "gains", "dtype": "f32",
     "n": 1024, "d": 64, "c": 512, "l": 0, "k": 0,
     "inputs": ["v","vsq","vmask","mindist","c","cmask"]},
    {"name": "update_l", "file": "d.hlo.txt", "kind": "update", "dtype": "f32",
     "n": 1024, "d": 64, "c": 0, "l": 0, "k": 0,
     "inputs": ["v","vsq","vmask","mindist","s"]}
  ]
}"#;

#[test]
fn prop_planned_bucket_fits_every_shard_and_merge() {
    // satellite invariant: the single planned bucket covers the merge
    // stage (full n) and every shard any partitioner produces
    let manifest = Manifest::parse(PLAN_MANIFEST, std::path::PathBuf::from("/tmp/pm")).unwrap();
    forall(
        "planned gains/update bucket fits all shards + merge",
        &Config { cases: 16, seed: 0x91A4 },
        |rng| {
            let n = 2 + rng.below(200);
            let d = 1 + rng.below(32);
            let shards = 1 + rng.below(8);
            let k = 1 + rng.below(5);
            let data: Vec<f32> = (0..n * d).map(|_| rng.normal()).collect();
            (n, d, shards, k, data)
        },
        |(n, d, shards, k, data)| {
            let mut req = PlanRequest::new(*n, *d, *shards, *k);
            req.batch = 64;
            req.cores = 8;
            let plan = ShardPlan::plan(Some(&manifest), &req);
            let g = plan
                .buckets
                .gains
                .as_ref()
                .ok_or("no gains bucket planned for an in-range shape")?;
            let u = plan
                .buckets
                .update
                .as_ref()
                .ok_or("no update bucket planned for an in-range shape")?;
            // merge stage (full n, d) fits
            if g.n < *n || g.d < *d || u.n < *n || u.d < *d {
                return Err(format!("merge shape ({n}, {d}) exceeds plan ({g:?})"));
            }
            // every shard of every partitioner fits the same bucket
            let v = Matrix::from_vec(*n, *d, data.clone());
            for name in PARTITIONERS {
                let p = build_partitioner(name, 5).expect("known partitioner");
                for part in p.partition(&v, *shards) {
                    if part.len() > g.n || part.len() > u.n {
                        return Err(format!(
                            "{name}: shard of {} rows exceeds planned bucket n={}",
                            part.len(),
                            g.n
                        ));
                    }
                }
            }
            // and the CPU split respects the core budget
            if plan.shard_workers * plan.oracle_threads > plan.cores {
                return Err(format!(
                    "split {}x{} exceeds {} cores",
                    plan.shard_workers, plan.oracle_threads, plan.cores
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_planned_equals_unplanned_shard_selection() {
    // satellite invariant: a plan changes scheduling (workers, threads,
    // buckets), never selection — planned and unplanned runs pick
    // identical exemplars with identical f
    forall(
        "planned sharded run == unplanned (indices + f bits)",
        &Config { cases: 10, seed: 0x71A2 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 60, 6, 2.0);
            let shards = 1 + rng.below(6);
            let k = 1 + rng.below(5);
            let cores = 1 + rng.below(8);
            (n, d, data, shards, k, cores)
        },
        |(n, d, data, shards, k, cores)| {
            let v = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let factory = |m: SharedMatrix, spec: &OracleSpec| {
                // honor the planned split like the launcher's CPU backend
                Box::new(CpuOracle::with_kernel_shared(
                    m,
                    CpuKernel::Scalar,
                    Precision::F32,
                    spec.threads_or(1),
                )) as Box<dyn Oracle>
            };
            let part = build_partitioner("round_robin", 0).expect("known partitioner");
            let greedy = Greedy::default();
            let unplanned = ShardedSummarizer::new(part.as_ref(), &greedy, *shards)
                .summarize(&v, &factory, *k);
            let mut req = PlanRequest::new(*n, *d, *shards, *k);
            req.cores = *cores;
            let mut planned_run = ShardedSummarizer::new(part.as_ref(), &greedy, *shards);
            planned_run.plan = Some(Arc::new(ShardPlan::plan(None, &req)));
            let planned = planned_run.summarize(&v, &factory, *k);
            if planned.merged.indices != unplanned.merged.indices {
                return Err(format!(
                    "P={shards} cores={cores}: {:?} != {:?}",
                    planned.merged.indices, unplanned.merged.indices
                ));
            }
            if planned.merged.f_final.to_bits() != unplanned.merged.f_final.to_bits() {
                return Err(format!(
                    "f {} != {}",
                    planned.merged.f_final, unplanned.merged.f_final
                ));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------ engine CPU fallback

#[test]
fn prop_engine_cpu_fallback_matches_scalar_oracle() {
    // satellite invariant: the engine's no-bucket fallback for gains and
    // update (DeviceDataset::fallback_*) matches the scalar CPU oracle
    // within kernel tolerance, for both fallback kernel backends
    forall(
        "engine gains/update CPU fallback == scalar oracle",
        &Config { cases: 12, seed: 0xFA11 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 50, 10, 2.0);
            let cands = arb_subset(rng, n, 6);
            let probe = rng.below(n);
            let threads = 1 + rng.below(3);
            let blocked = rng.below(2) == 1;
            (n, d, data, cands, probe, threads, blocked)
        },
        |(n, d, data, cands, probe, threads, blocked)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            let cfg = EngineConfig {
                cpu_kernel: if *blocked { CpuKernel::Blocked } else { CpuKernel::Scalar },
                cpu_threads: *threads,
                ..Default::default()
            };
            let mut ds = DeviceDataset::new(v.clone());
            let mut scalar = CpuOracle::new(v.clone());
            let tol = |r: f32| 1e-3 * (1.0 + r.abs());

            // state after one fold, like a mid-run optimizer
            let mut mind = scalar.vsq().to_vec();
            fold_mindist(&mut mind, &scalar.dist_col(*probe));

            // gains: engine fallback takes gathered candidate rows
            let want = scalar.gains(&mind, cands);
            let got = ds.fallback_gains(&cfg, &mind, &v.gather(cands));
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                if (a - b).abs() > tol(*a) {
                    return Err(format!("gains[{i}]: {a} vs {b}"));
                }
            }

            // update: new mindist folds the probe's distance column; the
            // f output matches the state-derived value
            let s_row = v.row(*probe).to_vec();
            let (nm, f) = ds.fallback_update(&cfg, Some(&mind), &s_row);
            let dcol = scalar.dist_col(*probe);
            for i in 0..*n {
                let want_m = mind[i].min(dcol[i]);
                if (nm[i] - want_m).abs() > tol(want_m) {
                    return Err(format!("update mindist[{i}]: {want_m} vs {}", nm[i]));
                }
            }
            let want_f = ebc::submodular::f_from_mindist(scalar.vsq(), &nm);
            if (f - want_f).abs() > tol(want_f) {
                return Err(format!("update f: {want_f} vs {f}"));
            }

            // dist-column case (mindist = None → raw distances)
            let (raw, _) = ds.fallback_update(&cfg, None, &s_row);
            for (i, (a, b)) in dcol.iter().zip(&raw).enumerate() {
                if (a - b).abs() > tol(*a) {
                    return Err(format!("dist_col[{i}]: {a} vs {b}"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------- blocked Gram-matrix kernel

#[test]
fn prop_gemm_nt_matches_naive_dots() {
    forall(
        "gemm_nt == naive row-row dot products (ragged tile shapes)",
        &Config { cases: 24, seed: 0x6E77 },
        |rng| {
            let m = rng.below(20); // includes 0
            let c = rng.below(20);
            let d = 1 + rng.below(40); // includes widths not divisible by 8
            let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..c * d).map(|_| rng.normal()).collect();
            (m, c, d, x, y)
        },
        |(m, c, d, x, y)| {
            let mut out = vec![0f32; m * c];
            gemm_nt(x, y, *d, *m, *c, &mut out);
            for i in 0..*m {
                for j in 0..*c {
                    let naive: f32 = (0..*d).map(|k| x[i * d + k] * y[j * d + k]).sum();
                    let got = out[i * c + j];
                    if (got - naive).abs() > 1e-3 * (1.0 + naive.abs()) {
                        return Err(format!("({i},{j}) m={m} c={c} d={d}: {got} vs {naive}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_blocked_kernel_matches_scalar() {
    // satellite invariant: blocked-GEMM gains / dist_col / eval equal the
    // scalar path within f32 tolerance, over random shapes including
    // n = 1 and d not divisible by the 8-wide micro-tile
    forall(
        "blocked gains/dist_col/eval == scalar within tolerance",
        &Config { cases: 16, seed: 0xB10C },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 45, 20, 2.0);
            let threads = 1 + rng.below(3);
            let cands = arb_subset(rng, n, 8);
            let set = arb_subset(rng, n, 5);
            let probe = rng.below(n);
            (n, d, data, threads, cands, set, probe)
        },
        |(n, d, data, threads, cands, set, probe)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            let scalar = EbcFunction::new(v.clone());
            let blocked =
                EbcFunction::with_kernel(v, CpuKernel::Blocked, Precision::F32, *threads);
            let tol = |r: f32| 1e-3 * (1.0 + r.abs());

            let (a, b) = (scalar.eval(set), blocked.eval(set));
            if (a - b).abs() > tol(a) {
                return Err(format!("eval {set:?}: {a} vs {b}"));
            }
            if !blocked.gains(scalar.vsq(), &[]).is_empty() {
                return Err("gains on empty candidate batch not empty".into());
            }
            let (ds, db) = (scalar.dist_col(*probe), blocked.dist_col(*probe));
            for (i, (x, y)) in ds.iter().zip(&db).enumerate() {
                if (x - y).abs() > tol(*x) {
                    return Err(format!("dist_col[{i}]: {x} vs {y}"));
                }
            }
            // gains from the state after folding the probe column
            let mut mind = scalar.vsq().to_vec();
            ebc::submodular::fold_mindist(&mut mind, &ds);
            let (gs, gb) = (scalar.gains(&mind, cands), blocked.gains(&mind, cands));
            for (i, (x, y)) in gs.iter().zip(&gb).enumerate() {
                if (x - y).abs() > tol(*x) {
                    return Err(format!("gains[{i}]: {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bf16_blocked_within_documented_bound() {
    // the software bf16 path demotes inputs to 8 significand bits
    // (relative input error 2^-9..2^-8); squared-distance terms amplify
    // that to ~2^-8·‖v‖², so the documented bound is a 2%-of-‖v‖²_max
    // absolute band plus 5% relative — much looser than f32, but bounded
    forall(
        "blocked bf16 eval/gains within the documented looser bound",
        &Config { cases: 12, seed: 0xBF16 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 40, 12, 2.0);
            let set = arb_subset(rng, n, 5);
            let cands = arb_subset(rng, n, 6);
            (n, d, data, set, cands)
        },
        |(n, d, data, set, cands)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            let scalar = EbcFunction::new(v.clone());
            let lp = EbcFunction::with_kernel(v, CpuKernel::Blocked, Precision::Bf16, 2);
            let vmax = scalar.vsq().iter().cloned().fold(0f32, f32::max);
            let tol = |r: f32| 0.05 * (1.0 + r.abs()) + 0.02 * vmax;

            let (a, b) = (scalar.eval(set), lp.eval(set));
            if (a - b).abs() > tol(a) {
                return Err(format!("eval: {a} vs {b} (vmax {vmax})"));
            }
            let gs = scalar.gains(scalar.vsq(), cands);
            let gb = lp.gains(scalar.vsq(), cands);
            for (i, (x, y)) in gs.iter().zip(&gb).enumerate() {
                if (x - y).abs() > tol(*x) {
                    return Err(format!("gains[{i}]: {x} vs {y} (vmax {vmax})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_greedy_selections_identical_scalar_vs_blocked() {
    // acceptance invariant: greedy selections — and the P = 1 sharded
    // run built on them — are identical between the scalar and blocked
    // f32 backends on the property-test seeds. The two kernels sum in
    // different orders, so a selection step whose top-two gains differ
    // by less than f32 noise could legitimately pick either candidate;
    // such a near-tie only counts as a pass if both selections reach
    // the same f under one reference evaluator — any genuine kernel bug
    // moves f by far more than last-bit noise.
    forall(
        "greedy + P=1 shard selections: scalar == blocked f32",
        &Config { cases: 10, seed: 0x9EED },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 50, 8, 2.0);
            let k = 1 + rng.below(6);
            let threads = 1 + rng.below(3);
            (n, d, data, k, threads)
        },
        |(n, d, data, k, threads)| {
            let v = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let greedy = Greedy::default();
            let scalar = greedy.run(&mut CpuOracle::new_shared(Arc::clone(&v)), *k);
            let blocked_oracle = |m: SharedMatrix, _spec: &OracleSpec| {
                Box::new(CpuOracle::with_kernel_shared(
                    m,
                    CpuKernel::Blocked,
                    Precision::F32,
                    *threads,
                )) as Box<dyn Oracle>
            };
            let blocked = greedy
                .run(blocked_oracle(Arc::clone(&v), &OracleSpec::unplanned()).as_mut(), *k);
            if scalar.indices != blocked.indices {
                let reference = EbcFunction::new(Matrix::clone(&v));
                let fa = reference.eval(&scalar.indices);
                let fb = reference.eval(&blocked.indices);
                if (fa - fb).abs() > 1e-4 * (1.0 + fa.abs()) {
                    return Err(format!(
                        "single-node: scalar {:?} (f={fa}) != blocked {:?} (f={fb})",
                        scalar.indices, blocked.indices
                    ));
                }
            }
            // P=1 shard through the blocked factory reproduces the
            // blocked single-node run bit for bit by construction
            // (same kernel, same thread count, gains independent of
            // candidate-batch composition) — strict.
            let part = build_partitioner("round_robin", 0).expect("known partitioner");
            let s = ShardedSummarizer::new(part.as_ref(), &greedy, 1);
            let res = s.summarize(&v, &blocked_oracle, *k);
            if res.merged.indices != blocked.indices {
                return Err(format!(
                    "P=1 shard: {:?} != single-node blocked {:?}",
                    res.merged.indices, blocked.indices
                ));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------ simd gemm micro-kernels

#[test]
fn prop_simd_gemm_bit_identical_to_blocked() {
    // tentpole invariant: the explicit-SIMD gemm (AVX2 / NEON / scalar
    // fallback, whichever the runtime detects) produces bit-identical
    // output to the blocked kernel — same mul+add (no FMA), same
    // k-sequential accumulation order — over ragged shapes including
    // m = 1, c = 1 and d not divisible by the 8-wide lane
    use ebc::linalg::gemm::gemm_nt_with;
    forall(
        "simd gemm_nt == blocked gemm_nt bit for bit (ragged shapes)",
        &Config { cases: 32, seed: 0x51D0 },
        |rng| {
            let m = rng.below(26); // includes 0 and 1
            let c = rng.below(26);
            let d = 1 + rng.below(70); // crosses the k-panel and lane widths
            let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..c * d).map(|_| rng.normal()).collect();
            let init: Vec<f32> = (0..m * c).map(|_| rng.normal()).collect();
            (m, c, d, x, y, init)
        },
        |(m, c, d, x, y, init)| {
            // accumulate into a non-zero out to exercise the += contract
            let mut blocked = init.clone();
            gemm_nt_with(CpuKernel::Blocked, x, y, *d, *m, *c, &mut blocked);
            let mut simd = init.clone();
            gemm_nt_with(CpuKernel::Simd, x, y, *d, *m, *c, &mut simd);
            for (i, (a, b)) in blocked.iter().zip(&simd).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "m={m} c={c} d={d} out[{i}]: blocked {a} != simd {b}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simd_oracle_bit_identical_to_blocked() {
    // tentpole invariant at the oracle level: eval / dist_col / gains
    // through the simd backend equal the blocked backend bit for bit,
    // for both precisions (the vectorized bf16 demote is bitwise equal
    // to the scalar demote, so lp matrices coincide too) — including
    // n = 1 and d not a multiple of the lane width
    forall(
        "simd eval/dist_col/gains == blocked, bitwise, f32 + bf16",
        &Config { cases: 12, seed: 0x51D1 },
        |rng| {
            let n = 1 + rng.below(45);
            let d = 1 + rng.below(20);
            let data: Vec<f32> = (0..n * d).map(|_| rng.normal() * 2.0).collect();
            let threads = 1 + rng.below(3);
            let cands = arb_subset(rng, n, 8);
            let set = arb_subset(rng, n, 5);
            let probe = rng.below(n);
            let bf16 = rng.below(2) == 1;
            (n, d, data, threads, cands, set, probe, bf16)
        },
        |(n, d, data, threads, cands, set, probe, bf16)| {
            let v = Matrix::from_vec(*n, *d, data.clone());
            let p = if *bf16 { Precision::Bf16 } else { Precision::F32 };
            let blocked = EbcFunction::with_kernel(v.clone(), CpuKernel::Blocked, p, *threads);
            let simd = EbcFunction::with_kernel(v, CpuKernel::Simd, p, *threads);

            let (a, b) = (blocked.eval(set), simd.eval(set));
            if a.to_bits() != b.to_bits() {
                return Err(format!("eval {set:?} ({p:?}): {a} != {b}"));
            }
            let (db, ds) = (blocked.dist_col(*probe), simd.dist_col(*probe));
            for (i, (x, y)) in db.iter().zip(&ds).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("dist_col[{i}] ({p:?}): {x} != {y}"));
                }
            }
            let mut mind = blocked.vsq().to_vec();
            fold_mindist(&mut mind, &db);
            let (gb, gs) = (blocked.gains(&mind, cands), simd.gains(&mind, cands));
            for (i, (x, y)) in gb.iter().zip(&gs).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("gains[{i}] ({p:?}): {x} != {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_greedy_selections_identical_scalar_vs_simd() {
    // acceptance invariant, simd edition: mirrors the scalar-vs-blocked
    // property above — near-ties resolved under one reference evaluator
    // — plus a strict check that simd and blocked trajectories coincide
    // exactly (they share one numerical contract)
    forall(
        "greedy selections: scalar == simd (tolerant), simd == blocked (exact)",
        &Config { cases: 10, seed: 0x51D2 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 50, 8, 2.0);
            let k = 1 + rng.below(6);
            let threads = 1 + rng.below(3);
            (n, d, data, k, threads)
        },
        |(n, d, data, k, threads)| {
            let v = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let greedy = Greedy::default();
            let scalar = greedy.run(&mut CpuOracle::new_shared(Arc::clone(&v)), *k);
            let with = |kernel: CpuKernel| {
                greedy.run(
                    &mut CpuOracle::with_kernel_shared(
                        Arc::clone(&v),
                        kernel,
                        Precision::F32,
                        *threads,
                    ),
                    *k,
                )
            };
            let simd = with(CpuKernel::Simd);
            let blocked = with(CpuKernel::Blocked);
            if simd.indices != blocked.indices
                || simd.f_final.to_bits() != blocked.f_final.to_bits()
            {
                return Err(format!(
                    "simd {:?} (f={}) != blocked {:?} (f={})",
                    simd.indices, simd.f_final, blocked.indices, blocked.f_final
                ));
            }
            if scalar.indices != simd.indices {
                let reference = EbcFunction::new(Matrix::clone(&v));
                let fa = reference.eval(&scalar.indices);
                let fb = reference.eval(&simd.indices);
                if (fa - fb).abs() > 1e-4 * (1.0 + fa.abs()) {
                    return Err(format!(
                        "scalar {:?} (f={fa}) != simd {:?} (f={fb})",
                        scalar.indices, simd.indices
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_forced_scalar_fallback_bit_identical() {
    // tentpole invariant: disabling runtime feature detection (the
    // degraded path on CPUs without AVX2/NEON) changes nothing — the
    // scalar fallback inside the simd backend is the blocked loop
    // itself, so outputs stay bit-identical. This is the only test in
    // this binary touching the process-global force flag; every other
    // simd property holds under either flag state by the same identity.
    use ebc::linalg::gemm::gemm_nt_with;
    forall(
        "simd with detection forced off == simd with detection on, bitwise",
        &Config { cases: 12, seed: 0x51D3 },
        |rng| {
            let m = 1 + rng.below(20);
            let c = 1 + rng.below(20);
            let d = 1 + rng.below(40);
            let x: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
            let y: Vec<f32> = (0..c * d).map(|_| rng.normal()).collect();
            (m, c, d, x, y)
        },
        |(m, c, d, x, y)| {
            let mut native = vec![0f32; m * c];
            gemm_nt_with(CpuKernel::Simd, x, y, *d, *m, *c, &mut native);
            let prev = ebc::linalg::simd::force_scalar(true);
            let mut forced = vec![0f32; m * c];
            gemm_nt_with(CpuKernel::Simd, x, y, *d, *m, *c, &mut forced);
            ebc::linalg::simd::force_scalar(prev);
            for (i, (a, b)) in native.iter().zip(&forced).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("m={m} c={c} d={d} out[{i}]: {a} != {b}"));
                }
            }
            Ok(())
        },
    );
}

// ------------------------------------------------- observability layer

#[test]
fn prop_registry_backed_metrics_snapshot_matches_field_mirror() {
    // satellite invariant: the registry-backed CoordinatorMetrics
    // produce byte-identical snapshot JSON to the pre-refactor
    // field-based builder fed the same values — the 13-key `metrics`
    // contract is frozen
    use ebc::coordinator::snapshot;
    use ebc::util::json::ObjBuilder;
    forall(
        "registry-backed metrics JSON == pre-refactor field-based shape",
        &Config { cases: 24, seed: 0x0B5E },
        |rng| {
            let vals: Vec<u64> = (0..11).map(|_| rng.next_u64() >> 40).collect();
            let secs = (rng.f32() as f64, rng.f32() as f64);
            (vals, secs)
        },
        |(vals, secs)| {
            let factory = Box::new(|m: SharedMatrix, _spec: &OracleSpec| {
                Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
            });
            let c = Coordinator::new(ServiceConfig::default(), factory);
            let m = &c.metrics;
            m.ingested.add(vals[0]);
            m.malformed.add(vals[1]);
            m.evicted.add(vals[2]);
            m.throttle_signals.add(vals[3]);
            m.refreshes.add(vals[4]);
            m.queries.add(vals[5]);
            m.fleet_queries.add(vals[6]);
            m.shard_runs.add(vals[7]);
            m.shard_retries.add(vals[8]);
            m.wire_bytes_total.add(vals[9]);
            m.replica_count.set(vals[10] as i64);
            m.refresh_seconds_total.add(secs.0);
            m.shard_merge_seconds_total.add(secs.1);

            // the pre-refactor builder, fed the same values in the same
            // key order
            let want = ObjBuilder::new()
                .int("ingested", vals[0] as usize)
                .int("malformed", vals[1] as usize)
                .int("evicted", vals[2] as usize)
                .int("throttle_signals", vals[3] as usize)
                .int("refreshes", vals[4] as usize)
                .num("refresh_seconds_total", secs.0)
                .int("queries", vals[5] as usize)
                .int("fleet_queries", vals[6] as usize)
                .int("shard_runs", vals[7] as usize)
                .num("shard_merge_seconds_total", secs.1)
                .int("replica_count", vals[10] as usize)
                .int("shard_retries", vals[8] as usize)
                .int("wire_bytes_total", vals[9] as usize)
                .build();
            let snap = snapshot::snapshot(&c);
            let got = snap
                .get("metrics")
                .ok_or_else(|| "metrics section missing".to_string())?;
            if got.dump() != want.dump() {
                return Err(format!(
                    "metrics drifted:\n got {}\nwant {}",
                    got.dump(),
                    want.dump()
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------- prune subsystem

/// `n` points in `c` tight Gaussian clusters — the regime pruning is
/// built for (dominated in-cluster rows transfer their charge to the
/// rows that cover them).
fn clustered_data(rng: &mut Rng, n: usize, d: usize, c: usize) -> Vec<f32> {
    let centers: Vec<Vec<f32>> = (0..c)
        .map(|_| rng.normal_vec(d).iter().map(|x| x * 6.0).collect())
        .collect();
    let mut data = Vec::with_capacity(n * d);
    for i in 0..n {
        for j in 0..d {
            data.push(centers[i % c][j] + 0.05 * rng.normal());
        }
    }
    data
}

#[test]
fn prop_prune_knobs_at_defaults_are_bit_identical_to_flat() {
    // tentpole invariant: prune 0 / fanout 0 / cap 0 / greedy merge is
    // byte-for-byte the pre-prune flat two-stage path — for every
    // partitioner over both local transports
    use ebc::api::{DatasetRef, Service, ShardSpec, SummarizeRequest};
    forall(
        "prune knobs at defaults == flat path (all partitioners, inproc + loopback)",
        &Config { cases: 5, seed: 0xF1A7 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 40, 5, 2.0);
            let shards = 1 + rng.below(5);
            let k = 1 + rng.below(4);
            (n, d, data, shards, k)
        },
        |(n, d, data, shards, k)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let service = Service::cpu();
            for name in PARTITIONERS {
                for transport in ["inproc", "loopback"] {
                    let base = SummarizeRequest::new(DatasetRef::Inline(Arc::clone(&v)), *k)
                        .cpu_kernel(CpuKernel::Scalar)
                        .threads(1)
                        .seed(33);
                    let spec = ShardSpec::new(*shards)
                        .partitioner(name)
                        .transport(transport)
                        .replicas(2);
                    let flat = service
                        .summarize(&base.clone().sharded(spec.clone()))
                        .map_err(|e| e.to_string())?;
                    let zeroed = service
                        .summarize(&base.sharded(
                            spec.prune(0.0).fanout(0).max_merge_n(0).merge_optimizer("greedy"),
                        ))
                        .map_err(|e| e.to_string())?;
                    if zeroed.exemplars != flat.exemplars
                        || zeroed.f_final.to_bits() != flat.f_final.to_bits()
                    {
                        return Err(format!("{name}/{transport}: zeroed prune knobs drifted"));
                    }
                    if zeroed.provenance.pruned_n != 0 || zeroed.provenance.merge_depth != 1 {
                        return Err(format!(
                            "{name}/{transport}: flat run misreported: pruned_n={} depth={}",
                            zeroed.provenance.pruned_n, zeroed.provenance.merge_depth
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_single_level_merge_tree_reproduces_flat_bitwise() {
    // tentpole invariant: a cap of n (caps nothing) forces the merge
    // tree, and fanout >= P collapses it to one root node — which must
    // run the identical union-candidate greedy the flat path runs
    use ebc::api::{DatasetRef, Service, ShardSpec, SummarizeRequest};
    forall(
        "fanout >= P + identity cap: merge tree == flat merge (bit for bit)",
        &Config { cases: 6, seed: 0x7EE5 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 48, 5, 2.0);
            let shards = 2 + rng.below(4);
            let k = 1 + rng.below(4);
            (n, d, data, shards, k)
        },
        |(n, d, data, shards, k)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let service = Service::cpu();
            for name in PARTITIONERS {
                let base = SummarizeRequest::new(DatasetRef::Inline(Arc::clone(&v)), *k)
                    .cpu_kernel(CpuKernel::Scalar)
                    .threads(1)
                    .seed(44);
                let flat = service
                    .summarize(&base.clone().sharded(ShardSpec::new(*shards).partitioner(name)))
                    .map_err(|e| e.to_string())?;
                let tree = service
                    .summarize(&base.sharded(
                        ShardSpec::new(*shards)
                            .partitioner(name)
                            .fanout(*shards + 1)
                            .max_merge_n(*n),
                    ))
                    .map_err(|e| e.to_string())?;
                if tree.exemplars != flat.exemplars
                    || tree.f_final.to_bits() != flat.f_final.to_bits()
                {
                    return Err(format!(
                        "{name}: tree {:?} (f={}) != flat {:?} (f={})",
                        tree.exemplars, tree.f_final, flat.exemplars, flat.f_final
                    ));
                }
                if tree.provenance.merge_depth != 1 {
                    return Err(format!(
                        "{name}: single-level tree reported depth {}",
                        tree.provenance.merge_depth
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pruned_greedy_keeps_quality_on_clusters() {
    // satellite invariant: on tight clusters, pruning half the ground
    // drops rows (reported in provenance) but the merged objective
    // stays within a constant factor of the exact two-stage run
    use ebc::api::{DatasetRef, Service, ShardSpec, SummarizeRequest};
    forall(
        "prune 0.5 on clusters: pruned_n > 0 and f >= 0.5 * exact",
        &Config { cases: 6, seed: 0xC1A5 },
        |rng| {
            let d = 4 + rng.below(4);
            let c = 3 + rng.below(3);
            let n = 96 + rng.below(64);
            let data = clustered_data(rng, n, d, c);
            let shards = 2 + rng.below(3);
            (n, d, data, c, shards)
        },
        |(n, d, data, c, shards)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let service = Service::cpu();
            let base = SummarizeRequest::new(DatasetRef::Inline(Arc::clone(&v)), *c)
                .cpu_kernel(CpuKernel::Scalar)
                .threads(1)
                .seed(5);
            let exact = service
                .summarize(&base.clone().sharded(ShardSpec::new(*shards)))
                .map_err(|e| e.to_string())?;
            let pruned = service
                .summarize(&base.sharded(ShardSpec::new(*shards).prune(0.5).fanout(2)))
                .map_err(|e| e.to_string())?;
            if pruned.provenance.pruned_n == 0 {
                return Err("prune 0.5 dropped nothing".into());
            }
            if pruned.provenance.pruned_n >= *n {
                return Err("prune dropped the whole ground".into());
            }
            if pruned.f_final < 0.5 * exact.f_final - 1e-6 {
                return Err(format!(
                    "pruned f {} < 0.5 * exact f {}",
                    pruned.f_final, exact.f_final
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_all_ones_weights_bit_identical_to_unweighted() {
    // satellite invariant: the weighted-eval seam with all-ones charges
    // is exactly the legacy objective — greedy selections, f bits and
    // raw evals all match
    forall(
        "all-ones charge weights == unweighted (greedy bits + eval bits)",
        &Config { cases: 16, seed: 0x11E5 },
        |rng| {
            let (n, d, data) = arb_dataset(rng, 40, 6, 2.0);
            let k = 1 + rng.below(n.min(5));
            let s = arb_subset(rng, n, 6);
            (n, d, data, k, s)
        },
        |(n, d, data, k, s)| {
            let v: SharedMatrix = Arc::new(Matrix::from_vec(*n, *d, data.clone()));
            let plain = Greedy::default().run(&mut CpuOracle::new_shared(Arc::clone(&v)), *k);
            let weighted = Greedy::default().run(
                &mut CpuOracle::new_shared(Arc::clone(&v)).with_weights(vec![1.0; *n]),
                *k,
            );
            if weighted.indices != plain.indices {
                return Err(format!(
                    "weighted {:?} != plain {:?}",
                    weighted.indices, plain.indices
                ));
            }
            if weighted.f_final.to_bits() != plain.f_final.to_bits() {
                return Err(format!("f {} != {}", weighted.f_final, plain.f_final));
            }
            let f = EbcFunction::new(Matrix::from_vec(*n, *d, data.clone()));
            let fw = EbcFunction::new(Matrix::from_vec(*n, *d, data.clone()))
                .with_weights(vec![1.0; *n]);
            if fw.eval(s).to_bits() != f.eval(s).to_bits() {
                return Err(format!("eval drifted on {s:?}"));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------------- rng sanity

#[test]
fn prop_sample_indices_always_distinct_in_range() {
    forall(
        "sample_indices: distinct, in range",
        &cfg(),
        |rng| {
            let n = 1 + rng.below(100);
            let m = rng.below(n + 1);
            (n, m, rng.next_u64())
        },
        |(n, m, seed)| {
            let mut r = Rng::new(*seed);
            let idx = r.sample_indices(*n, *m);
            if idx.len() != *m {
                return Err("wrong count".into());
            }
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            if s.len() != *m || s.iter().any(|&i| i >= *n) {
                return Err(format!("invalid sample {idx:?}"));
            }
            Ok(())
        },
    );
}
