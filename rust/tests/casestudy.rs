//! Case-study integration tests: the paper's §6 qualitative Table 2
//! expectations must hold on the simulated campaigns, end-to-end through
//! the real optimizer (reduced cycle fidelity keeps runtime sane; the
//! full d=3524 run is exercised by `examples/injection_molding` and the
//! table2 bench).

use ebc::imm::casestudy::{
    fig4_table, run_table2, summarize_case, table2_text, validate_expectations,
};
use ebc::imm::simulator::MeltPressureModel;
use ebc::imm::{generate_dataset_with, Part, ProcessState};
use ebc::linalg::Matrix;
use ebc::optim::{Greedy, Optimizer, RandomSelection};
use ebc::submodular::{CpuOracle, Oracle};

const SAMPLES: usize = 256;
const SEED: u64 = 20260711;

fn cpu(m: Matrix) -> Box<dyn Oracle> {
    Box::new(CpuOracle::new(m))
}

#[test]
fn table2_expectations_hold_for_all_ten_datasets() {
    let results = run_table2(&Greedy { batch: 4096 }, &cpu, 5, SAMPLES, SEED);
    assert_eq!(results.len(), 10);
    let mut failures = Vec::new();
    for r in &results {
        if let Err(e) = validate_expectations(r) {
            failures.push(format!("{}/{}: {e}", r.part.name(), r.state.name()));
        }
    }
    assert!(
        failures.is_empty(),
        "paper §6 expectations violated:\n  {}\n\n{}",
        failures.join("\n  "),
        table2_text(&results, 5)
    );
}

#[test]
fn greedy_beats_random_on_every_campaign() {
    for part in Part::all() {
        for state in ProcessState::all() {
            let ds = generate_dataset_with(part, state, SEED, 128);
            let g = summarize_case(ds, &Greedy { batch: 4096 }, &cpu, 5);
            let ds2 = generate_dataset_with(part, state, SEED, 128);
            let r = summarize_case(ds2, &RandomSelection { seed: 3 }, &cpu, 5);
            assert!(
                g.f_value >= r.f_value * 0.999,
                "{}/{}: greedy {} < random {}",
                part.name(),
                state.name(),
                g.f_value,
                r.f_value
            );
        }
    }
}

#[test]
fn fig4_regrind_representatives_show_both_effects() {
    // the paper's Fig. 4: across regrind levels, max melt pressure AND
    // plasticization time are affected
    let ds = generate_dataset_with(Part::Plate, ProcessState::Regrind, SEED, 512);
    let model = {
        let mut m = MeltPressureModel::new(Part::Plate.spec());
        m.samples = 512;
        m
    };
    let res = summarize_case(ds, &Greedy { batch: 4096 }, &cpu, 5);

    // order representatives by regrind section
    let mut by_section: Vec<(usize, usize)> = res
        .reps
        .iter()
        .map(|&i| (res.dataset.section[i], i))
        .collect();
    by_section.sort_unstable();
    assert!(by_section.len() >= 4, "{by_section:?}");

    let lo_sec = by_section.first().unwrap();
    let hi_sec = by_section.last().unwrap();
    assert!(hi_sec.0 > lo_sec.0);
    let peak_lo = MeltPressureModel::peak_of(res.dataset.cycles.row(lo_sec.1));
    let peak_hi = MeltPressureModel::peak_of(res.dataset.cycles.row(hi_sec.1));
    assert!(
        peak_lo > peak_hi + 30.0,
        "peak should drop with regrind: {peak_lo} vs {peak_hi}"
    );
    let params = ebc::imm::simulator::CycleParams::default();
    let plast_lo = model.plast_samples_of(res.dataset.cycles.row(lo_sec.1), &params);
    let plast_hi = model.plast_samples_of(res.dataset.cycles.row(hi_sec.1), &params);
    assert!(
        plast_lo > plast_hi,
        "plasticization should shorten with regrind: {plast_lo} vs {plast_hi}"
    );
}

#[test]
fn fig4_export_has_five_distinct_curves() {
    let ds = generate_dataset_with(Part::Plate, ProcessState::Regrind, SEED, 256);
    let res = summarize_case(ds, &Greedy { batch: 4096 }, &cpu, 5);
    let t = fig4_table(&res);
    assert_eq!(t.header.len(), 1 + res.reps.len());
    assert_eq!(t.rows.len(), 256);
    // header names carry the regrind percentage
    assert!(t.header[1].contains("regrind"));
    // columns differ (distinct cycles)
    let c1: Vec<&String> = t.rows.iter().map(|r| &r[1]).collect();
    let c2: Vec<&String> = t.rows.iter().map(|r| &r[2]).collect();
    assert_ne!(c1, c2);
}

#[test]
fn doe_covers_many_operation_points_with_large_k() {
    // paper: 43 points; with k=43 the cover reaches 33 sections, the
    // plate 28 — i.e. clearly more than half but fewer than all.
    let ds = generate_dataset_with(Part::Cover, ProcessState::Doe, SEED, 128);
    let res = summarize_case(ds, &Greedy { batch: 4096 }, &cpu, 43);
    let mut secs: Vec<usize> = res.reps.iter().map(|&i| res.dataset.section[i]).collect();
    secs.sort_unstable();
    secs.dedup();
    assert!(
        secs.len() >= 20 && secs.len() <= 43,
        "sections covered: {}",
        secs.len()
    );
}

#[test]
fn startup_representative_order_is_stable_across_backends_seeds() {
    // determinism: same seed -> same representatives
    let a = summarize_case(
        generate_dataset_with(Part::Cover, ProcessState::StartUp, 5, 128),
        &Greedy { batch: 1024 },
        &cpu,
        5,
    );
    let b = summarize_case(
        generate_dataset_with(Part::Cover, ProcessState::StartUp, 5, 128),
        &Greedy { batch: 64 },
        &cpu,
        5,
    );
    assert_eq!(a.reps, b.reps);
}
