//! Coverage-guided fuzzing of every wire decoder.
//!
//! The contract (same one `tests/wire_torture.rs` checks with seeded
//! mutations): an arbitrary byte string is classified or rejected with
//! a typed `WireError` — decoders never panic, never overflow, and
//! never allocate from a hostile length field. libFuzzer supplies the
//! bytes; any panic or sanitizer fault is a finding.

#![no_main]

use ebc::shard::wire::{
    decode_goodbye, decode_heartbeat, decode_hello, decode_job, decode_request, decode_result,
    frame_kind,
};
use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    // classification first: whatever it says, every decoder must still
    // hold the no-panic contract on the raw bytes
    let _ = frame_kind(data);
    let _ = decode_job(data);
    let _ = decode_result(data);
    let _ = decode_request(data);
    let _ = decode_hello(data);
    let _ = decode_heartbeat(data);
    let _ = decode_goodbye(data);
});
