//! Paper Table 1: min/mean/max speedups across the N / l / k sweep
//! variations, in two halves:
//!
//! 1. **Measured on this testbed**: the batched engine (f32 + bf16)
//!    against the ST and MT CPU baselines, over the same sweep as the
//!    fig2 bench.
//! 2. **Modeled for the paper's devices** (Quadro RTX 5000 vs Xeon
//!    W-2155, Jetson TX2 vs Cortex-A72) via the calibrated roofline
//!    model, over the paper's actual sweep values — regenerating the
//!    shape of the published table.
//!
//! Emits `bench_results/table1_{measured,modeled}.csv`.

use ebc::bench::report::Reporter;
use ebc::bench::workload::{fig2_workload, Fig2Sweep};
use ebc::bench::{full_mode, measure, Settings};
use ebc::engine::{DeviceDataset, Engine, EngineConfig, Precision};
use ebc::gpumodel::{
    a72_mt, speedup, xeon_mt, EbcWorkload, ModelPrecision, A72, QUADRO_RTX_5000, TX2, XEON_W2155,
};
use ebc::runtime::Runtime;
use ebc::submodular::EbcFunction;
use ebc::util::stats::MinMeanMax;
use ebc::util::threadpool::default_threads;
use std::time::Duration;

fn settings() -> Settings {
    Settings {
        warmup: 1,
        min_iters: 2,
        min_time: Duration::from_millis(50),
        max_iters: 20,
    }
}

fn fmt_mmm(m: &MinMeanMax) -> Vec<String> {
    vec![
        format!("{:.1}x", m.min),
        format!("{:.1}x", m.mean),
        format!("{:.1}x", m.max),
    ]
}

fn main() {
    // ---------------- measured half -------------------------------------
    let rt = Runtime::discover().expect("run `make artifacts` first");
    let eng32 = Engine::new(rt.clone(), EngineConfig { precision: Precision::F32, cpu_fallback: false, ..Default::default() });
    let eng16 = Engine::new(rt, EngineConfig { precision: Precision::Bf16, cpu_fallback: false, ..Default::default() });
    let sweep = Fig2Sweep::scaled(!full_mode());
    let threads = default_threads();
    let s = settings();

    // per-axis collections of speedups
    let mut sp: std::collections::BTreeMap<(&str, &str), Vec<f64>> = Default::default();
    let mut points: Vec<(&str, usize, usize, usize)> = Vec::new();
    for &n in &sweep.n_values {
        points.push(("N", n, sweep.base_l, sweep.base_k));
    }
    for &l in &sweep.l_values {
        points.push(("l", sweep.base_n, l, sweep.base_k));
    }
    for &k in &sweep.k_values {
        points.push(("k", sweep.base_n, sweep.base_l, k));
    }

    for (axis, n, l, k) in &points {
        let problem = fig2_workload(*n, *l, *k, sweep.d, 0x7AB1);
        let refs = problem.set_refs();
        let f = EbcFunction::new(problem.ground.clone());
        let st = measure(&s, || {
            std::hint::black_box(f.eval_sets_st(&refs));
        })
        .mean;
        let mt = measure(&s, || {
            std::hint::black_box(f.eval_sets_mt(&refs, threads));
        })
        .mean;
        let mut ds = DeviceDataset::new(problem.ground.clone());
        let x32 = measure(&s, || {
            std::hint::black_box(eng32.eval_sets(&mut ds, &refs).unwrap());
        })
        .mean;
        let mut ds2 = DeviceDataset::new(problem.ground.clone());
        let x16 = measure(&s, || {
            std::hint::black_box(eng16.eval_sets(&mut ds2, &refs).unwrap());
        })
        .mean;
        sp.entry((axis, "f32_st")).or_default().push(st / x32);
        sp.entry((axis, "f32_mt")).or_default().push(mt / x32);
        sp.entry((axis, "bf16_st")).or_default().push(st / x16);
        sp.entry((axis, "bf16_mt")).or_default().push(mt / x16);
        eprintln!("  {axis}: N={n} l={l} k={k} done");
    }

    let mut rep = Reporter::new(
        "Table 1 (measured, this testbed) — engine speedup over CPU baselines",
        &["axis", "variant", "min", "mean", "max"],
    );
    let mut csv = Reporter::new("t1m", &["axis", "variant", "min", "mean", "max"]);
    for ((axis, variant), vals) in &sp {
        let m = MinMeanMax::of(vals);
        let mut row = vec![axis.to_string(), variant.to_string()];
        row.extend(fmt_mmm(&m));
        rep.row(&row);
        csv.row(&[
            axis.to_string(),
            variant.to_string(),
            format!("{:.3}", m.min),
            format!("{:.3}", m.mean),
            format!("{:.3}", m.max),
        ]);
    }
    rep.print();
    csv.save_csv("table1_measured").expect("save");

    // ---------------- modeled half (paper devices, paper sweep) ---------
    // the paper's actual sweep values (§5.1)
    let paper_n: Vec<usize> = vec![1000, 29500, 100_000, 200_000, 400_000];
    let paper_l: Vec<usize> = vec![1000, 3785, 10_000, 18_000, 26_070];
    let paper_k: Vec<usize> = vec![10, 45, 150, 290, 430];
    let base = (50_000usize, 5_000usize, 10usize);
    let mut model_points: Vec<(&str, EbcWorkload)> = Vec::new();
    for &n in &paper_n {
        model_points.push(("N", EbcWorkload { n, l: base.1, k: base.2, d: 100 }));
    }
    for &l in &paper_l {
        model_points.push(("l", EbcWorkload { n: base.0, l, k: base.2, d: 100 }));
    }
    for &k in &paper_k {
        model_points.push(("k", EbcWorkload { n: base.0, l: base.1, k, d: 100 }));
    }

    let xeon_mt = xeon_mt();
    let a72_mt = a72_mt();
    let pairs: Vec<(&str, _, _, _)> = vec![
        ("Quadro fp32 vs Xeon ST", &QUADRO_RTX_5000, ModelPrecision::Fp32, &XEON_W2155),
        ("Quadro fp32 vs Xeon MT", &QUADRO_RTX_5000, ModelPrecision::Fp32, &xeon_mt),
        ("Quadro fp16 vs Xeon ST", &QUADRO_RTX_5000, ModelPrecision::Fp16, &XEON_W2155),
        ("Quadro fp16 vs Xeon MT", &QUADRO_RTX_5000, ModelPrecision::Fp16, &xeon_mt),
        ("TX2 fp32 vs A72 ST", &TX2, ModelPrecision::Fp32, &A72),
        ("TX2 fp32 vs A72 MT", &TX2, ModelPrecision::Fp32, &a72_mt),
        ("TX2 fp16 vs A72 ST", &TX2, ModelPrecision::Fp16, &A72),
        ("TX2 fp16 vs A72 MT", &TX2, ModelPrecision::Fp16, &a72_mt),
    ];
    // paper Table 1 reference bands for the shape check (min..max over all axes)
    let paper_bands: &[(&str, f64, f64)] = &[
        ("Quadro fp32 vs Xeon ST", 34.0, 72.0),
        ("Quadro fp32 vs Xeon MT", 3.3, 5.1),
        ("Quadro fp16 vs Xeon ST", 8.5, 438.2),
        ("Quadro fp16 vs Xeon MT", 0.8, 30.8),
        ("TX2 fp32 vs A72 ST", 4.3, 6.0),
        ("TX2 fp32 vs A72 MT", 1.5, 2.7),
        ("TX2 fp16 vs A72 ST", 5.1, 35.5),
        ("TX2 fp16 vs A72 MT", 1.3, 15.8),
    ];

    let mut rep2 = Reporter::new(
        "Table 1 (modeled, paper devices + paper sweep) — roofline predictions",
        &["pair", "min", "mean", "max", "paper_band"],
    );
    let mut csv2 = Reporter::new("t1p", &["pair", "min", "mean", "max"]);
    for (name, fast, pf, slow) in &pairs {
        let vals: Vec<f64> = model_points
            .iter()
            .map(|(_, w)| speedup(fast, *pf, slow, ModelPrecision::Fp32, w))
            .collect();
        let m = MinMeanMax::of(&vals);
        let band = paper_bands
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, lo, hi)| format!("{lo}-{hi}x"))
            .unwrap_or_default();
        let mut row = vec![name.to_string()];
        row.extend(fmt_mmm(&m));
        row.push(band);
        rep2.row(&row);
        csv2.row(&[
            name.to_string(),
            format!("{:.2}", m.min),
            format!("{:.2}", m.mean),
            format!("{:.2}", m.max),
        ]);
    }
    rep2.print();
    let p = csv2.save_csv("table1_modeled").expect("save");
    println!("\nwrote {}", p.display());
}
