//! Kernel-scaling bench: the CPU oracle hot path (`gains` / `dist_col`
//! / `eval`) across kernel backends (scalar baseline vs the blocked
//! Gram-matrix backend), precisions (f32 / software-bf16) and thread
//! counts — the CPU companion to the paper's Table 1 precision axis.
//! Emits `BENCH_kernel.json` plus `bench_results/kernel_scaling.csv`.
//!
//!     cargo bench --bench kernel_scaling
//!
//! `EBC_BENCH_QUICK=1` shrinks the workload; `EBC_BENCH_FULL=1` runs
//! the acceptance-sized N=20k, d=32, C=1024 sweep.

use ebc::api::{DatasetRef, SummarizeRequest};
use ebc::bench::kernel_scaling::{kernel_report, save_bench_json, split_report};
use ebc::bench::{
    full_mode, kernel_scaling_sweep, quick_mode, shard_split_sweep, KernelSweepConfig, Settings,
};

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();
    // the workload travels as an api request (same façade as the CLI);
    // the sweep derives its shape from the validated request
    let (n, c, threads): (usize, usize, Vec<usize>) = if full_mode() {
        (20_000, 1024, vec![1, 2, 4, 8])
    } else if quick_mode() {
        (2_000, 128, vec![1, 2])
    } else {
        (8_000, 512, vec![1, 2, 4])
    };
    let base = SummarizeRequest::new(DatasetRef::synthetic(n, 32, 7), 1).batch(c);
    let cfg = KernelSweepConfig::from_request(&base, threads)?;
    println!(
        "kernel sweep: N={} d={} C={} threads={:?}",
        cfg.n, cfg.d, cfg.c, cfg.thread_counts
    );
    let points = kernel_scaling_sweep(&cfg, &Settings::default());

    let rep = kernel_report(
        "CPU kernel scaling (scalar baseline vs blocked Gram-matrix)",
        &points,
    );
    rep.print();

    let shard_counts: &[usize] = if quick_mode() { &[2] } else { &[2, 4] };
    let splits = shard_split_sweep(&cfg, shard_counts, &Settings::default());
    split_report("planned vs unplanned shard split (blocked f32 gains)", &splits).print();

    let json_path = std::path::Path::new("BENCH_kernel.json");
    save_bench_json(json_path, &cfg, &points, &splits)?;
    match rep.save_csv("kernel_scaling") {
        Ok(path) => println!("\nwrote {} and {}", json_path.display(), path.display()),
        Err(e) => println!("\nwrote {} (csv export failed: {e})", json_path.display()),
    }
    Ok(())
}
