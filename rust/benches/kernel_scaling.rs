//! Kernel-scaling bench: the CPU oracle hot path (`gains` / `dist_col`
//! / `eval`) across kernel backends (scalar baseline vs the blocked
//! Gram-matrix backend), precisions (f32 / software-bf16) and thread
//! counts — the CPU companion to the paper's Table 1 precision axis.
//! Emits `BENCH_kernel.json` plus `bench_results/kernel_scaling.csv`.
//!
//!     cargo bench --bench kernel_scaling
//!
//! `EBC_BENCH_QUICK=1` shrinks the workload; `EBC_BENCH_FULL=1` runs
//! the acceptance-sized N=20k, d=32, C=1024 sweep.

use ebc::bench::kernel_scaling::{kernel_report, save_bench_json, split_report};
use ebc::bench::{
    full_mode, kernel_scaling_sweep, quick_mode, shard_split_sweep, KernelSweepConfig, Settings,
};

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();
    let cfg = if full_mode() {
        KernelSweepConfig::default()
    } else if quick_mode() {
        KernelSweepConfig { n: 2_000, d: 32, c: 128, thread_counts: vec![1, 2], seed: 7 }
    } else {
        KernelSweepConfig { n: 8_000, d: 32, c: 512, thread_counts: vec![1, 2, 4], seed: 7 }
    };
    println!(
        "kernel sweep: N={} d={} C={} threads={:?}",
        cfg.n, cfg.d, cfg.c, cfg.thread_counts
    );
    let points = kernel_scaling_sweep(&cfg, &Settings::default());

    let rep = kernel_report(
        "CPU kernel scaling (scalar baseline vs blocked Gram-matrix)",
        &points,
    );
    rep.print();

    let shard_counts: &[usize] = if quick_mode() { &[2] } else { &[2, 4] };
    let splits = shard_split_sweep(&cfg, shard_counts, &Settings::default());
    split_report("planned vs unplanned shard split (blocked f32 gains)", &splits).print();

    let json_path = std::path::Path::new("BENCH_kernel.json");
    save_bench_json(json_path, &cfg, &points, &splits)?;
    match rep.save_csv("kernel_scaling") {
        Ok(path) => println!("\nwrote {} and {}", json_path.display(), path.display()),
        Err(e) => println!("\nwrote {} (csv export failed: {e})", json_path.display()),
    }
    Ok(())
}
