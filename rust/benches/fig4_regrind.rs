//! Paper Fig. 4: the five representatives of the plate/regrind campaign,
//! exported as melt-pressure curves + an ASCII rendition, demonstrating
//! the two viscosity effects (peak injection pressure shift,
//! plasticization-time shift). Emits `bench_results/fig4_regrind_plate.csv`.

use ebc::bench::quick_mode;
use ebc::engine::{Engine, EngineConfig, Precision, XlaOracle};
use ebc::imm::casestudy::{fig4_table, summarize_case};
use ebc::imm::simulator::{CycleParams, MeltPressureModel};
use ebc::imm::{generate_dataset_with, Part, ProcessState, CYCLE_SAMPLES};
use ebc::linalg::Matrix;
use ebc::optim::Greedy;
use ebc::runtime::Runtime;
use ebc::submodular::Oracle;

fn ascii_plot(curves: &[(String, Vec<f32>)], width: usize, height: usize) {
    let maxv = curves
        .iter()
        .flat_map(|(_, c)| c.iter())
        .cloned()
        .fold(f32::MIN, f32::max);
    let symbols = ['0', '1', '2', '3', '4'];
    let mut grid = vec![vec![' '; width]; height];
    for (ci, (_, curve)) in curves.iter().enumerate() {
        for x in 0..width {
            let idx = x * curve.len() / width;
            let v = curve[idx].max(0.0);
            let y = ((v / maxv) * (height - 1) as f32).round() as usize;
            let row = height - 1 - y.min(height - 1);
            grid[row][x] = symbols[ci % symbols.len()];
        }
    }
    println!("melt pressure [0..{maxv:.0} bar] over the cycle window:");
    for row in grid {
        println!("|{}|", row.into_iter().collect::<String>());
    }
    for (ci, (name, _)) in curves.iter().enumerate() {
        println!("  {} = {name}", symbols[ci % symbols.len()]);
    }
}

fn main() {
    let samples = if quick_mode() { 512 } else { CYCLE_SAMPLES };
    let rt = Runtime::discover().expect("run `make artifacts` first");
    let engine = Engine::new(rt, EngineConfig { precision: Precision::F32, cpu_fallback: true, ..Default::default() });
    let factory = move |m: Matrix| -> Box<dyn Oracle> {
        Box::new(XlaOracle::new(engine.clone(), m))
    };

    let ds = generate_dataset_with(Part::Plate, ProcessState::Regrind, 20260711, samples);
    let res = summarize_case(ds, &Greedy { batch: 256 }, &factory, 5);
    println!(
        "plate/regrind representatives (cycle -> section): {:?}",
        res.reps
            .iter()
            .map(|&i| (i, res.dataset.section[i]))
            .collect::<Vec<_>>()
    );

    // the two Fig. 4 effects, quantified per representative
    let mut model = MeltPressureModel::new(Part::Plate.spec());
    model.samples = samples;
    let params = CycleParams::default();
    println!("\n{:<8} {:>8} {:>12} {:>16}", "cycle", "regrind", "peak [bar]", "plast [samples]");
    let mut by_sec: Vec<&usize> = res.reps.iter().collect();
    by_sec.sort_by_key(|&&i| res.dataset.section[i]);
    let mut curves = Vec::new();
    for &&rep in &by_sec {
        let curve = res.dataset.cycles.row(rep);
        let sec = res.dataset.section[rep];
        println!(
            "{:<8} {:>7}% {:>12.1} {:>16}",
            rep,
            sec * 25,
            MeltPressureModel::peak_of(curve),
            model.plast_samples_of(curve, &params)
        );
        curves.push((format!("cycle {rep} ({}% regrind)", sec * 25), curve.to_vec()));
    }
    println!();
    ascii_plot(&curves, 100, 18);

    let t = fig4_table(&res);
    let dir = std::env::var("EBC_BENCH_OUT").unwrap_or_else(|_| "bench_results".into());
    let path = std::path::Path::new(&dir).join("fig4_regrind_plate.csv");
    t.save(&path).expect("save");
    println!("\nwrote {} ({} samples x {} curves)", path.display(), samples, res.reps.len());
}
