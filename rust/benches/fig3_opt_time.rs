//! Paper Fig. 3: wall-clock time to produce a summary of size k from
//! N = 1000 melt-pressure time series (d = 3524), with Greedy and
//! Three Sieves, on the accelerated engine and on the ST CPU baseline.
//!
//! Default k sweep is scaled for this single-core container;
//! `EBC_BENCH_FULL=1` extends toward the paper's k=430.
//! Emits `bench_results/fig3_opt_time.csv`.

use ebc::bench::full_mode;
use ebc::bench::report::{fmt_secs, Reporter};
use ebc::engine::{Engine, EngineConfig, Precision, XlaOracle};
use ebc::imm::{generate_dataset_with, Part, ProcessState, CYCLE_SAMPLES};
use ebc::optim::{Greedy, Optimizer, ThreeSieves};
use ebc::runtime::Runtime;
use ebc::submodular::CpuOracle;

fn main() {
    let rt = Runtime::discover().expect("run `make artifacts` first");
    let engine = Engine::new(rt, EngineConfig { precision: Precision::F32, cpu_fallback: true, ..Default::default() });

    // the paper's dataset shape: 1000 time series, d = 3524
    let samples = CYCLE_SAMPLES;
    let ds = generate_dataset_with(Part::Plate, ProcessState::Stable, 0xF13, samples);
    let data = ds.cycles;
    println!("fig3 dataset: {}x{}", data.rows(), data.cols());

    let ks: Vec<usize> = if full_mode() {
        vec![5, 10, 25, 50, 100, 200, 430]
    } else {
        vec![5, 10, 20]
    };

    let mut rep = Reporter::new(
        "Fig. 3 — optimization time vs summary size k (N=1000, d=3524)",
        &["k", "greedy_xla", "greedy_cpu", "three_sieves_xla", "three_sieves_cpu"],
    );
    let mut csv = Reporter::new(
        "fig3",
        &["k", "greedy_xla_s", "greedy_cpu_s", "three_sieves_xla_s", "three_sieves_cpu_s"],
    );

    for &k in &ks {
        let greedy = Greedy { batch: 256 };
        let sieves = ThreeSieves { epsilon: 0.1, t: 50 };

        let mut xo = XlaOracle::new(engine.clone(), data.clone());
        let g_xla = greedy.run(&mut xo, k);

        let mut co = CpuOracle::new(data.clone());
        let g_cpu = greedy.run(&mut co, k);

        let mut xo2 = XlaOracle::new(engine.clone(), data.clone());
        let t_xla = sieves.run(&mut xo2, k);

        let mut co2 = CpuOracle::new(data.clone());
        let t_cpu = sieves.run(&mut co2, k);

        rep.row(&[
            k.to_string(),
            fmt_secs(g_xla.wall_seconds),
            fmt_secs(g_cpu.wall_seconds),
            fmt_secs(t_xla.wall_seconds),
            fmt_secs(t_cpu.wall_seconds),
        ]);
        csv.row(&[
            k.to_string(),
            format!("{:.4}", g_xla.wall_seconds),
            format!("{:.4}", g_cpu.wall_seconds),
            format!("{:.4}", t_xla.wall_seconds),
            format!("{:.4}", t_cpu.wall_seconds),
        ]);
        eprintln!(
            "  k={k}: greedy xla {:.2}s cpu {:.2}s | 3sieves xla {:.2}s cpu {:.2}s (f: {:.1} vs {:.1})",
            g_xla.wall_seconds, g_cpu.wall_seconds, t_xla.wall_seconds, t_cpu.wall_seconds,
            g_xla.f_final, t_xla.f_final,
        );
    }
    rep.print();
    let p = csv.save_csv("fig3_opt_time").expect("save");
    println!("\nwrote {}", p.display());
    println!(
        "\npaper shape check: Three Sieves' single pass is k-insensitive while\n\
         Greedy grows ~linearly in k — compare the two columns above."
    );
}
