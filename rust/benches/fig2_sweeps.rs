//! Paper Fig. 2: wall-clock runtime of multi-set EBC evaluation as a
//! function of N (ground size), l (number of sets), and k (set size),
//! for the ST CPU baseline (Alg. 1), the MT CPU baseline (§4.1) and the
//! batched accelerator engine (f32 + bf16).
//!
//! The sweep is scaled to this testbed (DESIGN.md §4); set
//! `EBC_BENCH_FULL=1` for larger sizes. Emits `bench_results/fig2_sweeps.csv`.

use ebc::bench::report::{fmt_secs, Reporter};
use ebc::bench::workload::{fig2_workload, Fig2Sweep};
use ebc::bench::{full_mode, measure, Settings};
use ebc::engine::{DeviceDataset, Engine, EngineConfig, Precision};
use ebc::runtime::Runtime;
use ebc::submodular::EbcFunction;
use ebc::util::threadpool::default_threads;
use std::time::Duration;

fn settings() -> Settings {
    Settings {
        warmup: 1,
        min_iters: if full_mode() { 5 } else { 2 },
        min_time: Duration::from_millis(if full_mode() { 500 } else { 50 }),
        max_iters: 50,
    }
}

struct Row {
    axis: &'static str,
    value: usize,
    st: f64,
    mt: f64,
    xla_f32: f64,
    xla_bf16: f64,
}

fn run_point(
    eng32: &Engine,
    eng16: &Engine,
    axis: &'static str,
    n: usize,
    l: usize,
    k: usize,
    d: usize,
    value: usize,
) -> Row {
    let problem = fig2_workload(n, l, k, d, 0xF16 + value as u64);
    let refs = problem.set_refs();
    let f = EbcFunction::new(problem.ground.clone());
    let threads = default_threads();
    let s = settings();

    let st = measure(&s, || {
        std::hint::black_box(f.eval_sets_st(&refs));
    });
    let mt = measure(&s, || {
        std::hint::black_box(f.eval_sets_mt(&refs, threads));
    });
    let mut ds32 = DeviceDataset::new(problem.ground.clone());
    let xla_f32 = measure(&s, || {
        std::hint::black_box(eng32.eval_sets(&mut ds32, &refs).unwrap());
    });
    let mut ds16 = DeviceDataset::new(problem.ground.clone());
    let xla_bf16 = measure(&s, || {
        std::hint::black_box(eng16.eval_sets(&mut ds16, &refs).unwrap());
    });
    Row {
        axis,
        value,
        st: st.mean,
        mt: mt.mean,
        xla_f32: xla_f32.mean,
        xla_bf16: xla_bf16.mean,
    }
}

fn main() {
    let rt = Runtime::discover().expect("run `make artifacts` first");
    let eng32 = Engine::new(rt.clone(), EngineConfig { precision: Precision::F32, cpu_fallback: false, ..Default::default() });
    let eng16 = Engine::new(rt, EngineConfig { precision: Precision::Bf16, cpu_fallback: false, ..Default::default() });
    let sweep = Fig2Sweep::scaled(!full_mode());

    let mut rows: Vec<Row> = Vec::new();
    println!(
        "fig2: base point N={} l={} k={} d={}",
        sweep.base_n, sweep.base_l, sweep.base_k, sweep.d
    );
    for &n in &sweep.n_values {
        rows.push(run_point(&eng32, &eng16, "N", n, sweep.base_l, sweep.base_k, sweep.d, n));
        eprintln!("  N={n} done");
    }
    for &l in &sweep.l_values {
        rows.push(run_point(&eng32, &eng16, "l", sweep.base_n, l, sweep.base_k, sweep.d, l));
        eprintln!("  l={l} done");
    }
    for &k in &sweep.k_values {
        rows.push(run_point(&eng32, &eng16, "k", sweep.base_n, sweep.base_l, k, sweep.d, k));
        eprintln!("  k={k} done");
    }

    let mut rep = Reporter::new(
        "Fig. 2 — runtime vs N / l / k (seconds, mean)",
        &["axis", "value", "cpu_st", "cpu_mt", "xla_f32", "xla_bf16", "xla32/st", "xla32/mt"],
    );
    for r in &rows {
        rep.row(&[
            r.axis.to_string(),
            r.value.to_string(),
            fmt_secs(r.st),
            fmt_secs(r.mt),
            fmt_secs(r.xla_f32),
            fmt_secs(r.xla_bf16),
            format!("{:.2}x", r.st / r.xla_f32),
            format!("{:.2}x", r.mt / r.xla_f32),
        ]);
    }
    rep.print();
    // CSV for plotting
    let mut csv = Reporter::new(
        "fig2 raw",
        &["axis", "value", "cpu_st_s", "cpu_mt_s", "xla_f32_s", "xla_bf16_s"],
    );
    for r in &rows {
        csv.row(&[
            r.axis.to_string(),
            r.value.to_string(),
            format!("{:.6}", r.st),
            format!("{:.6}", r.mt),
            format!("{:.6}", r.xla_f32),
            format!("{:.6}", r.xla_bf16),
        ]);
    }
    let path = csv.save_csv("fig2_sweeps").expect("save csv");
    println!("\nwrote {}", path.display());

    // the paper's qualitative shape: runtime grows monotonically with
    // each axis for every implementation
    for axis in ["N", "l", "k"] {
        let series: Vec<&Row> = rows.iter().filter(|r| r.axis == axis).collect();
        for w in series.windows(2) {
            if w[1].st < w[0].st * 0.7 {
                eprintln!(
                    "WARNING: ST runtime not monotone on {axis}: {} -> {}",
                    w[0].st, w[1].st
                );
            }
        }
    }
}
