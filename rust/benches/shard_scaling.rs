//! Shard-scaling bench: two-stage sharded summarization wall-clock and
//! quality as a function of the shard count P and the per-shard
//! optimizer, on a generated IMM campaign — the horizontal companion to
//! the paper's vertical (accelerator) scaling figures. Every
//! measurement routes through the `ebc::api` façade. Emits
//! `bench_results/shard_scaling_bench.csv`.
//!
//!     cargo bench --bench shard_scaling
//!
//! `EBC_BENCH_QUICK=1` shrinks the sweep; `EBC_THREADS` caps the
//! shard-stage worker pool.

use ebc::api::{DatasetRef, Service};
use ebc::bench::report::fmt_secs;
use ebc::bench::{quick_mode, shard_scaling_sweep, Reporter, ShardSweepConfig};
use ebc::imm::{Part, ProcessState};

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();
    let quick = quick_mode();
    let samples = if quick { 128 } else { 512 };
    let service = Service::cpu();
    // materialize the campaign once; every sweep cell aliases it
    let data =
        DatasetRef::imm(Part::Cover, ProcessState::Stable, samples, 7).materialize()?;
    let dataset = DatasetRef::Inline(data);

    let algorithms: Vec<String> = if quick {
        vec!["greedy".into()]
    } else {
        vec!["greedy".into(), "lazy_greedy".into(), "stochastic_greedy".into()]
    };
    let mut points = Vec::new();
    for partitioner in ["round_robin", "hash", "locality"] {
        // planned (P x T <= cores split) vs the legacy unplanned fan-out
        for planned in [false, true] {
            let cfg = ShardSweepConfig {
                k: 10,
                shard_counts: vec![1, 2, 4, 8],
                algorithms: algorithms.clone(),
                partitioner: partitioner.into(),
                planned,
                ..Default::default()
            };
            let pts = shard_scaling_sweep(&service, &dataset, &cfg)?;
            points.extend(pts.into_iter().map(|p| (partitioner, p)));
        }
    }

    let mut rep = Reporter::new(
        "shard scaling (IMM cover/stable)",
        &[
            "partitioner", "algorithm", "P", "plan", "shard_s", "merge_s", "total_s",
            "speedup", "quality",
        ],
    );
    for (partitioner, p) in &points {
        rep.row(&[
            partitioner.to_string(),
            p.algorithm.clone(),
            p.shards.to_string(),
            p.plan.clone(),
            fmt_secs(p.shard_seconds),
            fmt_secs(p.merge_seconds),
            fmt_secs(p.total_seconds),
            format!("{:.2}x", p.speedup),
            format!("{:.3}", p.quality_ratio),
        ]);
    }
    rep.print();
    let path = rep.save_csv("shard_scaling_bench")?;
    println!("\nwrote {}", path.display());
    Ok(())
}
