//! Ablation benches for the design choices DESIGN.md §7 calls out:
//!
//! * `batch`     — candidate-batch size vs greedy wall-clock (the paper's
//!                 "multi-set batching is the point" claim);
//! * `precision` — f32 vs bf16 end-to-end runtime + numerics drift;
//! * `lazy`      — Greedy vs LazyGreedy vs StochasticGreedy oracle work;
//! * `ivm`       — EBC vs IVM: summary sensitivity to the IVM kernel
//!                 scale (the paper's §1 motivation for EBC);
//! * `drain`     — adaptive vs fixed ingest batching under burst load.
//!
//! Run a subset: `cargo bench --bench ablations -- batch precision`.

use ebc::bench::report::{fmt_secs, Reporter};
use ebc::config::schema::ServiceConfig;
use ebc::coordinator::{Coordinator, CycleRecord};
use ebc::engine::{Engine, EngineConfig, Precision, XlaOracle};
use ebc::linalg::Matrix;
use ebc::optim::{Greedy, LazyGreedy, Optimizer, StochasticGreedy};
use ebc::runtime::Runtime;
use ebc::submodular::ivm::IvmFunction;
use ebc::submodular::{CpuOracle, Oracle};
use ebc::util::rng::Rng;

fn engine(p: Precision) -> Engine {
    let rt = Runtime::discover().expect("run `make artifacts` first");
    Engine::new(rt, EngineConfig { precision: p, cpu_fallback: true, ..Default::default() })
}

fn ablation_batch() {
    let mut rng = Rng::new(1);
    let v = Matrix::random_normal(4000, 100, &mut rng);
    let mut rep = Reporter::new(
        "ablation: candidate batch size (greedy, N=4000, d=100, k=10, XLA)",
        &["batch", "wall", "oracle_calls"],
    );
    for batch in [32, 128, 512, 1024, 4096] {
        let mut o = XlaOracle::new(engine(Precision::F32), v.clone());
        let r = Greedy { batch }.run(&mut o, 10);
        rep.row(&[batch.to_string(), fmt_secs(r.wall_seconds), r.oracle_calls.to_string()]);
    }
    rep.print();
    println!("expected shape: larger batches amortize per-launch overhead until the C bucket saturates.");
}

fn ablation_precision() {
    let mut rng = Rng::new(2);
    let v = Matrix::random_normal(4000, 100, &mut rng);
    let mut rep = Reporter::new(
        "ablation: precision (greedy, N=4000, d=100, k=10)",
        &["precision", "wall", "f_final", "rel_err_vs_f32"],
    );
    let mut base_f = None;
    for (name, p) in [("f32", Precision::F32), ("bf16", Precision::Bf16)] {
        let mut o = XlaOracle::new(engine(p), v.clone());
        let r = Greedy { batch: 1024 }.run(&mut o, 10);
        let rel = base_f
            .map(|b: f32| ((r.f_final - b) / b).abs())
            .unwrap_or(0.0);
        if base_f.is_none() {
            base_f = Some(r.f_final);
        }
        rep.row(&[
            name.to_string(),
            fmt_secs(r.wall_seconds),
            format!("{:.6}", r.f_final),
            format!("{rel:.2e}"),
        ]);
    }
    rep.print();
}

fn ablation_lazy() {
    let mut rng = Rng::new(3);
    let v = Matrix::random_normal(2000, 100, &mut rng);
    let mut rep = Reporter::new(
        "ablation: optimizer work (N=2000, d=100, k=20, CPU oracle)",
        &["optimizer", "wall", "distance_work", "f_final"],
    );
    let opts: Vec<(&str, Box<dyn Optimizer>)> = vec![
        ("greedy", Box::new(Greedy { batch: 1024 })),
        ("lazy_greedy", Box::new(LazyGreedy { refresh_batch: 64 })),
        ("stochastic_greedy", Box::new(StochasticGreedy { epsilon: 0.1, seed: 1 })),
    ];
    for (name, opt) in opts {
        let mut o = CpuOracle::new(v.clone());
        let r = opt.run(&mut o, 20);
        rep.row(&[
            name.to_string(),
            fmt_secs(r.wall_seconds),
            format!("{:.2e}", r.oracle_work as f64),
            format!("{:.5}", r.f_final),
        ]);
    }
    rep.print();
    println!("expected shape: lazy << greedy work at equal f; stochastic trades a little f for far less work.");
}

fn ablation_ivm() {
    // the paper's §1 motivation: IVM needs a tuned kernel scale; EBC is
    // parameter-free. Measure how the IVM-greedy summary *changes* as the
    // scale varies, vs. the (fixed) EBC summary, on an IMM campaign.
    use ebc::imm::{generate_dataset_with, Part, ProcessState};
    let ds = generate_dataset_with(Part::Plate, ProcessState::Regrind, 5, 256);
    let v = ds.cycles;
    let ebc_reps = {
        let mut o = CpuOracle::new(v.clone());
        Greedy { batch: 4096 }.run(&mut o, 5).indices
    };

    // greedy on IVM via naive evaluation (small k)
    let ivm_greedy = |scale: f32| -> Vec<usize> {
        let f = IvmFunction::new(v.clone(), scale, 1.0);
        let mut set: Vec<usize> = Vec::new();
        for _ in 0..5 {
            let mut best = (usize::MAX, f32::NEG_INFINITY);
            let cur = f.eval(&set);
            for c in 0..v.rows() {
                if set.contains(&c) {
                    continue;
                }
                let mut ext = set.clone();
                ext.push(c);
                let g = f.eval(&ext) - cur;
                if g > best.1 {
                    best = (c, g);
                }
            }
            set.push(best.0);
        }
        set
    };

    let mut rep = Reporter::new(
        "ablation: IVM kernel-scale sensitivity (plate/regrind, k=5)",
        &["method", "scale", "reps", "overlap_with_ebc"],
    );
    rep.row(&[
        "ebc".into(),
        "-".into(),
        format!("{ebc_reps:?}"),
        "5/5".into(),
    ]);
    // scales around the data's natural distance scale
    for scale in [50.0f32, 500.0, 5000.0] {
        let reps = ivm_greedy(scale);
        let overlap = reps.iter().filter(|r| ebc_reps.contains(r)).count();
        rep.row(&[
            "ivm".into(),
            format!("{scale}"),
            format!("{reps:?}"),
            format!("{overlap}/5"),
        ]);
    }
    rep.print();
    println!("expected shape: IVM's selection changes with the scale; EBC has no such knob.");
}

fn ablation_drain() {
    // burst-load coordinator: adaptive drain vs fixed small batches
    let run = |adaptive: bool| -> (f64, u64) {
        let mut cfg = ServiceConfig::default();
        cfg.summary.k = 3;
        cfg.summary.refresh_every = 200;
        cfg.summary.window = 256;
        cfg.coordinator.queue_capacity = 512;
        cfg.coordinator.ingest_batch = if adaptive { 16 } else { 16 };
        let factory: ebc::coordinator::OracleFactory =
            Box::new(|m: ebc::linalg::SharedMatrix, _spec: &ebc::engine::OracleSpec| {
                Box::new(CpuOracle::new_shared(m)) as Box<dyn Oracle>
            });
        let mut c = Coordinator::new(cfg, factory);
        let mut rng = Rng::new(7);
        let t0 = std::time::Instant::now();
        // bursty producer: 4000 cycles in bursts of 200
        let mut seq = 0u64;
        for _burst in 0..20 {
            for _ in 0..200 {
                let vals: Vec<f32> = (0..64).map(|_| rng.normal()).collect();
                c.offer(CycleRecord { machine: "m".into(), seq, values: vals });
                seq += 1;
            }
            if adaptive {
                while c.queue_len() > 0 {
                    c.tick();
                }
            } else {
                // fixed drain: exactly one base batch per tick
                for _ in 0..13 {
                    c.tick();
                }
            }
        }
        while c.queue_len() > 0 {
            c.tick();
        }
        (t0.elapsed().as_secs_f64(), c.metrics.evicted.get())
    };
    let (t_a, ev_a) = run(true);
    let (t_f, ev_f) = run(false);
    let mut rep = Reporter::new(
        "ablation: adaptive vs fixed ingest drain (burst load)",
        &["policy", "wall", "evicted"],
    );
    rep.row(&["adaptive".into(), fmt_secs(t_a), ev_a.to_string()]);
    rep.row(&["fixed".into(), fmt_secs(t_f), ev_f.to_string()]);
    rep.print();
    println!("expected shape: fixed drains fall behind bursts and evict; adaptive keeps up.");
}

fn ablation_reduce() {
    // the paper's §7 future work, implemented: reduce d=3524 cycles
    // before summarizing — fidelity vs speed
    use ebc::imm::{generate_dataset_with, Part, ProcessState};
    use ebc::reduce::{distance_distortion_ok_fraction, Pca, RandomProjection, Reducer};
    let ds = generate_dataset_with(Part::Plate, ProcessState::Regrind, 9, 3524);
    let full = ds.cycles;
    let t0 = std::time::Instant::now();
    let base = Greedy { batch: 256 }.run(
        &mut XlaOracle::new(engine(Precision::F32), full.clone()),
        5,
    );
    let t_full = t0.elapsed().as_secs_f64();

    let mut rep = Reporter::new(
        "ablation: dimensionality reduction before summarization (plate/regrind, d=3524, k=5)",
        &["reducer", "dims", "summarize_wall", "rep_overlap", "dist_ok@10%"],
    );
    rep.row(&[
        "none".into(),
        "3524".into(),
        fmt_secs(t_full),
        "5/5".into(),
        "1.00".into(),
    ]);
    let cases: Vec<(&str, Box<dyn Reducer>)> = vec![
        ("rp-512", Box::new(RandomProjection::new(3524, 512, 1))),
        ("rp-128", Box::new(RandomProjection::new(3524, 128, 1))),
        ("pca-16", Box::new(Pca::fit(&full, 16, 8, 2))),
    ];
    for (name, red) in cases {
        let t0 = std::time::Instant::now();
        let small = red.transform(&full);
        let t_reduce = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let r = Greedy { batch: 256 }.run(
            &mut XlaOracle::new(engine(Precision::F32), small.clone()),
            5,
        );
        let t_sum = t1.elapsed().as_secs_f64();
        let overlap = r.indices.iter().filter(|i| base.indices.contains(i)).count();
        let ok = distance_distortion_ok_fraction(&full, &small, 0.10, 300, 3);
        rep.row(&[
            name.into(),
            red.out_dim().to_string(),
            format!("{} (+{} reduce)", fmt_secs(t_sum), fmt_secs(t_reduce)),
            format!("{overlap}/5"),
            format!("{ok:.2}"),
        ]);
    }
    rep.print();
    println!("expected shape: PCA keeps the physical modes (high overlap at tiny d);\nRP needs JL-scale dims but is fit-free/streamable.");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a == name);
    if want("batch") {
        ablation_batch();
    }
    if want("reduce") {
        ablation_reduce();
    }
    if want("precision") {
        ablation_precision();
    }
    if want("lazy") {
        ablation_lazy();
    }
    if want("ivm") {
        ablation_ivm();
    }
    if want("drain") {
        ablation_drain();
    }
}
