//! Paper Table 2: the top-5 representatives for all ten injection-
//! molding campaigns (2 parts x 5 process states) at full fidelity
//! (d = 3524 unless EBC_BENCH_QUICK=1), through the accelerated engine.
//! Also validates the paper's process-knowledge expectations and prints
//! per-campaign summarization latency (the §6 "reasonable time frame"
//! claim). Emits `bench_results/table2.csv`.

use ebc::bench::quick_mode;
use ebc::engine::{Engine, EngineConfig, Precision, XlaOracle};
use ebc::imm::casestudy::{run_table2, table2_text, validate_expectations};
use ebc::imm::CYCLE_SAMPLES;
use ebc::linalg::Matrix;
use ebc::optim::Greedy;
use ebc::runtime::Runtime;
use ebc::submodular::Oracle;
use ebc::bench::report::Reporter;

fn main() {
    let samples = if quick_mode() { 512 } else { CYCLE_SAMPLES };
    let rt = Runtime::discover().expect("run `make artifacts` first");
    let engine = Engine::new(rt, EngineConfig { precision: Precision::F32, cpu_fallback: true, ..Default::default() });
    let factory = move |m: Matrix| -> Box<dyn Oracle> {
        Box::new(XlaOracle::new(engine.clone(), m))
    };

    eprintln!("generating + summarizing 10 campaigns at d={samples} ...");
    let results = run_table2(&Greedy { batch: 256 }, &factory, 5, samples, 20260711);
    println!("{}", table2_text(&results, 5));

    let mut csv = Reporter::new(
        "table2",
        &["part", "state", "rep1", "rep2", "rep3", "rep4", "rep5", "f_value", "wall_s", "ok"],
    );
    let mut failures = 0;
    for r in &results {
        let ok = match validate_expectations(r) {
            Ok(()) => true,
            Err(e) => {
                eprintln!("EXPECTATION FAIL {}/{}: {e}", r.part.name(), r.state.name());
                failures += 1;
                false
            }
        };
        let rep = |i: usize| r.reps.get(i).map(|x| x.to_string()).unwrap_or_default();
        csv.row(&[
            r.part.name().to_string(),
            r.state.name().to_string(),
            rep(0),
            rep(1),
            rep(2),
            rep(3),
            rep(4),
            format!("{:.2}", r.f_value),
            format!("{:.3}", r.wall_seconds),
            ok.to_string(),
        ]);
        println!(
            "  {:>6}/{:<16} wall {:>7.2}s  f={:.1}  reps {:?}",
            r.part.name(),
            r.state.name(),
            r.wall_seconds,
            r.f_value,
            r.reps
        );
    }
    let p = csv.save_csv("table2").expect("save");
    println!("\nwrote {}", p.display());
    let total: f64 = results.iter().map(|r| r.wall_seconds).sum();
    println!(
        "total summarization time for the whole study: {total:.1}s \
         ({failures} expectation failures)"
    );
    if failures > 0 {
        std::process::exit(1);
    }
}
