# Developer entry points. `make verify` is the tier-1 gate CI runs.

.PHONY: verify build test bench bench-kernel bench-shard perf-gate pgo lint doc artifacts

verify:
	cargo build --release && cargo test -q

build:
	cargo build --release

test:
	cargo test -q

bench:
	EBC_BENCH_QUICK=1 cargo bench

# CPU kernel backend sweep on a small preset; emits BENCH_kernel.json.
bench-kernel:
	cargo run --release -- kernel-bench --n 4000 --d 32 --c 256 --threads 1,2,4

# Shard transport sweep over the loopback replica fleet; emits
# BENCH_shard.json (the artifact the CI bench job uploads).
bench-shard:
	cargo run --release -- shard-bench --transport loopback --replicas 3 \
		--samples 64 --k 5 --shards 1,2,4 --out BENCH_shard.json

# Fresh sweep gated against the committed BENCH_kernel.json baseline
# (>15% regression on any blocked/simd point fails; see bench/perf.md).
perf-gate:
	cargo run --release -- kernel-bench --n 4000 --d 32 --c 256 \
		--threads 1,2,4 --out BENCH_kernel.new.json
	python3 bench/perf_gate.py --baseline BENCH_kernel.json \
		--candidate BENCH_kernel.new.json

# Profile-guided build: instrument -> profile on kernel-bench -> rebuild.
pgo:
	bench/run_pgo.sh

lint:
	cargo fmt --check && cargo clippy --all-targets -- -D warnings

# rustdoc with warnings denied — CI runs the same (docs job)
doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# AOT-lower the Pallas/JAX graphs to HLO text + manifest (requires the
# Python layer; the Rust binary is self-contained afterwards).
artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
