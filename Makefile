# Developer entry points. `make verify` is the tier-1 gate CI runs.

.PHONY: verify build test bench artifacts

verify:
	cargo build --release && cargo test -q

build:
	cargo build --release

test:
	cargo test -q

bench:
	EBC_BENCH_QUICK=1 cargo bench

# AOT-lower the Pallas/JAX graphs to HLO text + manifest (requires the
# Python layer; the Rust binary is self-contained afterwards).
artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
