//! Fleet-level summarization demo: a simulated fleet of six injection
//! molding machines streams cycles into the coordinator; an operator
//! then asks for (a) each machine's cached summary and (b) the reserved
//! `@fleet` query, which pools every machine's window and answers
//! through the sharded two-stage summarizer (`ebc::shard`) — partition
//! across P shards, per-shard greedy on pool workers, GreeDi-style
//! merge scored against the pooled ground set.
//!
//! Self-contained on the CPU oracle (no AOT artifacts needed):
//!
//!     cargo run --release --example fleet_summary [-- --shards 4]

use ebc::api::Service;
use ebc::config::schema::ServiceConfig;
use ebc::coordinator::{RouteResult, SimulatedFleet, FLEET_QUERY};
use ebc::imm::{Part, ProcessState};

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let arg = |flag: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    };
    let samples = arg("--samples", 256);
    let shards = arg("--shards", 4);

    let mut cfg = ServiceConfig::default();
    cfg.name = "fleet-demo".into();
    cfg.summary.k = 5;
    cfg.summary.refresh_every = 200;
    cfg.summary.window = 400;
    cfg.coordinator.queue_capacity = 8192;
    cfg.engine.cpu_kernel = ebc::linalg::CpuKernel::Scalar;
    cfg.engine.cpu_threads = 1; // fleet plans override per oracle
    cfg.shard.shards = shards;
    cfg.shard.partitioner = "locality".into();

    // the api façade wires the oracle factory + fleet planner from cfg
    let coordinator = Service::cpu().coordinator(cfg);

    let mut fleet = SimulatedFleet::new(
        &[
            ("imm-cover-1", Part::Cover, ProcessState::Stable),
            ("imm-cover-2", Part::Cover, ProcessState::StartUp),
            ("imm-cover-3", Part::Cover, ProcessState::Doe),
            ("imm-plate-1", Part::Plate, ProcessState::Regrind),
            ("imm-plate-2", Part::Plate, ProcessState::Downtimes),
            ("imm-plate-3", Part::Plate, ProcessState::Stable),
        ],
        samples,
        20260729,
    );
    let t0 = std::time::Instant::now();
    let n = coordinator.run_stream(&mut fleet);
    println!(
        "ingested {n} cycles from 6 machines in {:.2}s\n",
        t0.elapsed().as_secs_f64()
    );

    println!("per-machine summaries (cached):");
    for name in coordinator.machine_names() {
        println!("  {name}: {}", coordinator.query(&name).describe());
    }

    println!("\nfleet query ({} shards, locality partitioning):", shards);
    match coordinator.query(FLEET_QUERY) {
        RouteResult::Fleet(f) => {
            println!(
                "  pooled {} cycles from {} machine(s), {} shard(s)",
                f.window_total, f.machines, f.shards
            );
            println!(
                "  stage 1 (parallel shard greedy): {:.3}s, stage 2 (merge): {:.3}s",
                f.shard_seconds, f.merge_seconds
            );
            println!("  f(S) = {:.4}", f.f_value);
            println!("  fleet representatives (machine, cycle seq):");
            for (machine, seq) in &f.representatives {
                println!("    {machine} @ seq {seq}");
            }
            assert!(!f.representatives.is_empty());
        }
        other => anyhow::bail!("unexpected fleet route: {other:?}"),
    }

    println!(
        "\nmetrics: fleet_queries={} shard_runs={} merge_total={:.3}s",
        coordinator.metrics.fleet_queries.get(),
        coordinator.metrics.shard_runs.get(),
        coordinator.metrics.shard_merge_seconds_total.get()
    );
    Ok(())
}
