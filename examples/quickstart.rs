//! Quickstart: summarize a synthetic dataset through the full stack —
//! AOT-compiled Pallas/JAX graphs driven from Rust via PJRT.
//!
//!     make artifacts && cargo run --release --example quickstart

use ebc::engine::{Engine, EngineConfig, Precision, XlaOracle};
use ebc::linalg::{CpuKernel, Matrix};
use ebc::optim::{Greedy, Optimizer};
use ebc::runtime::Runtime;
use ebc::submodular::CpuOracle;
use ebc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();

    // 1. a dataset: 2000 vectors in 100 dimensions, three blobs
    // centers away from the origin: EBC's auxiliary exemplar e0 = 0 means
    // data at the origin is "covered for free" and would never be picked
    let mut rng = Rng::new(42);
    let mut data = Vec::with_capacity(2000 * 100);
    for i in 0..2000 {
        let center = 5.0 + (i % 3) as f32 * 8.0;
        for _ in 0..100 {
            data.push(center + rng.normal());
        }
    }
    let v = Matrix::from_vec(2000, 100, data);

    // 2. the engine: loads artifacts/, compiles on the PJRT CPU client
    let rt = Runtime::discover()?;
    let engine = Engine::new(rt, EngineConfig { precision: Precision::F32, cpu_fallback: true, ..Default::default() });
    let mut oracle = XlaOracle::new(engine, v.clone());

    // 3. greedy summarization, k = 6
    let result = Greedy::default().run(&mut oracle, 6);

    println!("representatives: {:?}", result.indices);
    println!("f(S) trajectory: {:?}", result.f_trajectory);
    println!(
        "wall: {:.3}s over {} oracle calls ({:.2e} scalar distances)",
        result.wall_seconds,
        result.oracle_calls,
        result.oracle_work as f64
    );

    // blobs at 0, 8, 16 -> the first three picks must hit three blobs
    let blobs: std::collections::BTreeSet<usize> =
        result.indices.iter().take(3).map(|i| i % 3).collect();
    assert_eq!(blobs.len(), 3, "expected one exemplar per blob");
    println!("OK: one exemplar per blob among the first three picks");

    // 4. same run on the blocked CPU Gram-matrix backend (no artifacts
    // needed) — selections match the accelerator path's CPU mirror
    let mut cpu = CpuOracle::with_kernel(
        v,
        CpuKernel::Blocked,
        Precision::F32,
        ebc::util::threadpool::default_threads(),
    );
    let cpu_result = Greedy::default().run(&mut cpu, 6);
    println!(
        "blocked CPU kernel: {:?} in {:.3}s",
        cpu_result.indices, cpu_result.wall_seconds
    );
    Ok(())
}
