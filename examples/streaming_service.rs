//! Streaming coordinator demo: a fleet of four simulated IMMs pushing
//! melt-pressure cycles through the backpressure queue into per-machine
//! sliding windows, with EBC summaries refreshed on the configured
//! cadence and served to "operator" queries — the deployment scenario
//! the paper's §6 motivates.
//!
//!     cargo run --release --example streaming_service [-- --samples 3524]

use ebc::config::schema::ServiceConfig;
use ebc::coordinator::{snapshot, Coordinator, RouteResult, SimulatedFleet};
use ebc::engine::{Engine, EngineConfig, Precision, XlaOracle};
use ebc::imm::{Part, ProcessState};
use ebc::runtime::Runtime;
use ebc::submodular::Oracle;

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let samples = args
        .iter()
        .position(|a| a == "--samples")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(512usize);

    let mut cfg = ServiceConfig::default();
    cfg.name = "demo-plant".into();
    cfg.summary.k = 5;
    cfg.summary.refresh_every = 100;
    cfg.summary.window = 500;
    cfg.coordinator.queue_capacity = 2048;
    cfg.coordinator.ingest_batch = 32;

    let rt = Runtime::discover()?;
    let engine = Engine::new(rt.clone(), EngineConfig { precision: Precision::F32, cpu_fallback: true, ..Default::default() });
    let factory = move |m: ebc::linalg::SharedMatrix, spec: &ebc::engine::OracleSpec| -> Box<dyn Oracle> {
        let mut engine = engine.clone();
        if let Some(plan) = &spec.plan {
            engine.set_plan(std::sync::Arc::clone(plan));
        }
        Box::new(XlaOracle::from_shared(engine, m))
    };
    let planner: ebc::engine::PlanSource = {
        let rt = rt.clone();
        Box::new(move |req| {
            std::sync::Arc::new(ebc::engine::ShardPlan::plan(Some(rt.manifest()), req))
        })
    };
    let coordinator = Coordinator::new(cfg, Box::new(factory)).with_planner(planner);

    let mut fleet = SimulatedFleet::new(
        &[
            ("imm-cover-1", Part::Cover, ProcessState::Stable),
            ("imm-cover-2", Part::Cover, ProcessState::StartUp),
            ("imm-plate-1", Part::Plate, ProcessState::Regrind),
            ("imm-plate-2", Part::Plate, ProcessState::Downtimes),
        ],
        samples,
        20260711,
    );

    println!("streaming {} cycles (d={samples}) through the coordinator ...", fleet.remaining());
    let t0 = std::time::Instant::now();
    let n = coordinator.run_stream(&mut fleet);
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "\nprocessed {n} cycles in {dt:.2}s -> {:.0} cycles/s ingest throughput",
        n as f64 / dt
    );
    let m = &coordinator.metrics;
    println!(
        "metrics: ingested={} evicted={} throttle={} refreshes={} (avg refresh {:.3}s)",
        m.ingested.get(),
        m.evicted.get(),
        m.throttle_signals.get(),
        m.refreshes.get(),
        m.refresh_seconds_total.get() / m.refreshes.get().max(1) as f64
    );

    println!("\noperator queries:");
    for name in ["imm-cover-1", "imm-cover-2", "imm-plate-1", "imm-plate-2", "imm-plate"] {
        let res = coordinator.query(name);
        println!("  {name:<14} -> {}", res.describe());
        if name == "imm-plate" {
            assert!(matches!(res, RouteResult::Ambiguous { .. }));
        }
    }

    print!(
        "\nmetrics (Prometheus text):\n{}",
        ebc::obs::expo::render_text(&coordinator.metrics.registry().snapshot())
    );
    let snap = snapshot::snapshot(&coordinator);
    let path = std::path::Path::new("bench_results").join("service_snapshot.json");
    std::fs::create_dir_all("bench_results")?;
    std::fs::write(&path, snap.dump())?;
    println!("snapshot -> {}", path.display());
    Ok(())
}
