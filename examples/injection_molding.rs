//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the paper's full §6 case
//! study at full fidelity — ten campaigns (2 parts x 5 process states,
//! 1000/860 cycles of d=3524 melt-pressure samples), summarized with
//! Greedy(k=5) through the accelerated engine, validated against the
//! paper's process-knowledge expectations, with Table 2 and the Fig. 4
//! export. Reports per-campaign latency — the paper's "summaries within
//! reasonable time frames" headline.
//!
//!     make artifacts && cargo run --release --example injection_molding
//!
//! Pass `--quick` for a reduced-fidelity smoke run (d=512).

use ebc::engine::{Engine, EngineConfig, Precision, XlaOracle};
use ebc::imm::casestudy::{fig4_table, run_table2, table2_text, validate_expectations};
use ebc::imm::{Part, ProcessState, CYCLE_SAMPLES};
use ebc::linalg::Matrix;
use ebc::optim::Greedy;
use ebc::runtime::Runtime;
use ebc::submodular::Oracle;

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();
    let quick = std::env::args().any(|a| a == "--quick");
    let samples = if quick { 512 } else { CYCLE_SAMPLES };

    let rt = Runtime::discover()?;
    let engine = Engine::new(rt, EngineConfig { precision: Precision::F32, cpu_fallback: true, ..Default::default() });
    let factory = move |m: Matrix| -> Box<dyn Oracle> {
        Box::new(XlaOracle::new(engine.clone(), m))
    };

    println!("injection-molding case study: 10 campaigns, d={samples}, k=5, backend=XLA");
    let t0 = std::time::Instant::now();
    let results = run_table2(&Greedy { batch: 256 }, &factory, 5, samples, 20260711);
    let total = t0.elapsed().as_secs_f64();

    println!("{}", table2_text(&results, 5));
    let mut failures = 0;
    for r in &results {
        let status = match validate_expectations(r) {
            Ok(()) => "OK  ".to_string(),
            Err(e) => {
                failures += 1;
                format!("FAIL ({e})")
            }
        };
        println!(
            "  {:>6}/{:<16} f={:>9.1}  summarize {:>6.2}s  {status}",
            r.part.name(),
            r.state.name(),
            r.f_value,
            r.wall_seconds
        );
    }

    // Fig. 4 export
    let r = results
        .iter()
        .find(|r| r.part == Part::Plate && r.state == ProcessState::Regrind)
        .expect("plate/regrind campaign");
    let path = std::path::Path::new("bench_results").join("fig4_regrind_plate.csv");
    fig4_table(r).save(&path)?;
    println!("\nFig. 4 curves -> {}", path.display());

    let summarize_total: f64 = results.iter().map(|r| r.wall_seconds).sum();
    println!(
        "\ntotal wall {total:.1}s (summarization {summarize_total:.1}s, \
         {:.2}s mean per campaign) — {failures} expectation failure(s)",
        summarize_total / results.len() as f64
    );
    if failures > 0 {
        anyhow::bail!("{failures} of the paper's Table-2 expectations failed");
    }
    println!("all of the paper's §6 expectations reproduced ✔");
    Ok(())
}
