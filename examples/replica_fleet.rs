//! Remote-shard-transport demo: fleet queries answered over a replica
//! fleet instead of the in-process threadpool.
//!
//! A simulated injection-molding fleet streams cycles into the
//! coordinator; `@fleet` queries fan their shards out over loopback
//! worker replicas through the versioned wire format (`ebc::shard::wire`
//! — the exact frames a socket transport would carry). The demo then
//! exercises the failure story: a replica is rigged to die mid-run
//! (its shards re-queue to the survivors, selection unchanged), and a
//! drained replica stops receiving work.
//!
//! Self-contained on the CPU oracle (no AOT artifacts needed):
//!
//!     cargo run --release --example replica_fleet [-- --replicas 4]

use ebc::api::Service;
use ebc::config::schema::ServiceConfig;
use ebc::coordinator::{Coordinator, RouteResult, SimulatedFleet, FLEET_QUERY};
use ebc::imm::{Part, ProcessState};
use ebc::shard::LoopbackReplicaTransport;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let arg = |flag: &str, default: usize| -> usize {
        args.iter()
            .position(|a| a == flag)
            .and_then(|i| args.get(i + 1))
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    };
    let samples = arg("--samples", 128);
    let replicas = arg("--replicas", 3);

    let mut cfg = ServiceConfig::default();
    cfg.name = "replica-fleet-demo".into();
    cfg.summary.k = 4;
    cfg.summary.refresh_every = 200;
    cfg.summary.window = 300;
    cfg.coordinator.queue_capacity = 8192;
    // `with_transport` below is what routes @fleet over the replica
    // fleet — the [shard] transport knob stays at its default so the
    // coordinator doesn't build a throwaway registry first
    cfg.shard.shards = 2 * replicas; // every replica sees work
    cfg.engine.cpu_kernel = ebc::linalg::CpuKernel::Scalar;
    cfg.engine.cpu_threads = 1; // fleet plans override per oracle

    // the api façade wires the oracle factory + fleet planner from cfg;
    // keep a handle to the replica fleet so we can drain/kill members
    let transport = Arc::new(LoopbackReplicaTransport::with_replicas(replicas, 1));
    let coordinator =
        Service::cpu().coordinator(cfg).with_transport(Box::new(Arc::clone(&transport)));

    let mut fleet = SimulatedFleet::new(
        &[
            ("imm-cover-1", Part::Cover, ProcessState::Stable),
            ("imm-cover-2", Part::Cover, ProcessState::StartUp),
            ("imm-plate-1", Part::Plate, ProcessState::Regrind),
            ("imm-plate-2", Part::Plate, ProcessState::Downtimes),
        ],
        samples,
        20260729,
    );
    let n = coordinator.run_stream(&mut fleet);
    println!("ingested {n} cycles from 4 machines; {replicas} loopback replica(s) registered\n");

    let fleet_reps = |c: &Coordinator| -> Vec<(String, u64)> {
        match c.query(FLEET_QUERY) {
            RouteResult::Fleet(f) => {
                println!(
                    "  {} shards over {} replica(s): f(S) = {:.4}, stage1 {:.3}s, merge {:.3}s",
                    f.shards,
                    c.transport_replica_count(),
                    f.f_value,
                    f.shard_seconds,
                    f.merge_seconds
                );
                f.representatives
            }
            other => panic!("unexpected fleet route: {other:?}"),
        }
    };

    println!("fleet query on the healthy replica fleet:");
    let healthy = fleet_reps(&coordinator);
    for (machine, seq) in &healthy {
        println!("    {machine} @ seq {seq}");
    }

    // rig one replica to die after its first shard of the next run
    println!("\nfleet query with replica-0 dying mid-run:");
    transport.fail_after("replica-0", 1);
    let degraded = fleet_reps(&coordinator);
    assert_eq!(
        degraded, healthy,
        "replica failure must not change the selection"
    );
    println!(
        "    selection identical; {} shard(s) re-queued to survivors",
        coordinator.metrics.shard_retries.get()
    );

    // drain another: graceful shutdown, no new shards
    transport.drain("replica-1");
    println!("\nfleet query with replica-1 drained:");
    let drained = fleet_reps(&coordinator);
    assert_eq!(drained, healthy);
    transport.with_registry(|reg| {
        for r in reg.iter() {
            println!(
                "    {:<10} state {:?}, {} shard(s) completed",
                r.id, r.state, r.jobs_done
            );
        }
    });

    let m = &coordinator.metrics;
    println!(
        "\nmetrics: fleet_queries={} shard_runs={} shard_retries={} replica_count={} \
         wire_bytes_total={}",
        m.fleet_queries.get(),
        m.shard_runs.get(),
        m.shard_retries.get(),
        m.replica_count.get(),
        m.wire_bytes_total.get()
    );
    Ok(())
}
