//! Programmable benchmark sweep: evaluate the multi-set work-matrix
//! path across a custom grid from the command line — the tool we used
//! for the perf pass (EXPERIMENTS.md §Perf).
//!
//!     cargo run --release --example benchmark_sweep -- \
//!         --n 1000,4000 --l 16,64 --k 10 --d 100 --backend xla,cpu_st

use ebc::bench::report::{fmt_secs, Reporter};
use ebc::bench::workload::fig2_workload;
use ebc::bench::{measure, Settings};
use ebc::engine::{DeviceDataset, Engine, EngineConfig, Precision};
use ebc::runtime::Runtime;
use ebc::submodular::EbcFunction;
use ebc::util::threadpool::default_threads;
use std::time::Duration;

fn parse_list(args: &[String], flag: &str, default: &str) -> Vec<usize> {
    let raw = args
        .iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string());
    raw.split(',').filter_map(|s| s.trim().parse().ok()).collect()
}

fn parse_str(args: &[String], flag: &str, default: &str) -> String {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
        .unwrap_or_else(|| default.to_string())
}

fn main() -> anyhow::Result<()> {
    ebc::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let ns = parse_list(&args, "--n", "1000,4000");
    let ls = parse_list(&args, "--l", "16,64");
    let ks = parse_list(&args, "--k", "10");
    let ds = parse_list(&args, "--d", "100");
    let backends = parse_str(&args, "--backend", "xla,cpu_st");
    let backends: Vec<&str> = backends.split(',').collect();

    let rt = Runtime::discover()?;
    let eng = Engine::new(rt, EngineConfig { precision: Precision::F32, cpu_fallback: true, ..Default::default() });
    let settings = Settings {
        warmup: 1,
        min_iters: 3,
        min_time: Duration::from_millis(100),
        max_iters: 25,
    };

    let mut rep = Reporter::new(
        "custom sweep — multi-set evaluation",
        &["n", "l", "k", "d", "backend", "mean", "p95"],
    );
    for &n in &ns {
        for &l in &ls {
            for &k in &ks {
                for &d in &ds {
                    let p = fig2_workload(n, l, k, d, 0xCAFE);
                    let refs = p.set_refs();
                    for b in &backends {
                        let summary = match *b {
                            "xla" => {
                                let mut dds = DeviceDataset::new(p.ground.clone());
                                measure(&settings, || {
                                    std::hint::black_box(
                                        eng.eval_sets(&mut dds, &refs).unwrap(),
                                    );
                                })
                            }
                            "cpu_st" => {
                                let f = EbcFunction::new(p.ground.clone());
                                measure(&settings, || {
                                    std::hint::black_box(f.eval_sets_st(&refs));
                                })
                            }
                            "cpu_mt" => {
                                let f = EbcFunction::new(p.ground.clone());
                                let t = default_threads();
                                measure(&settings, || {
                                    std::hint::black_box(f.eval_sets_mt(&refs, t));
                                })
                            }
                            other => {
                                eprintln!("unknown backend '{other}', skipping");
                                continue;
                            }
                        };
                        rep.row(&[
                            n.to_string(),
                            l.to_string(),
                            k.to_string(),
                            d.to_string(),
                            b.to_string(),
                            fmt_secs(summary.mean),
                            fmt_secs(summary.p95),
                        ]);
                    }
                }
            }
        }
    }
    rep.print();
    let path = rep.save_csv("custom_sweep")?;
    println!("\nwrote {}", path.display());
    Ok(())
}
