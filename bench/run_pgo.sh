#!/usr/bin/env bash
# Repeatable profile-guided-optimization build of the ebc binary,
# profiled on the kernel-bench sweep (the hot gains/dist_col/eval path).
#
# Usage: bench/run_pgo.sh [profile-dir]
#
# Stages:
#   1. build with -Cprofile-generate
#   2. run the kernel-bench workload to collect .profraw profiles
#   3. merge with llvm-profdata (must match rustc's LLVM — install via
#      `rustup component add llvm-tools` if not on PATH)
#   4. rebuild with -Cprofile-use
#
# The PGO binary lands in target/release/ebc-summarizer as usual; compare
# before/after with `make bench-kernel` + `bench/perf_gate.py
# --mode seconds` (same machine, so absolute seconds are meaningful).
set -euo pipefail

cd "$(dirname "$0")/.."
PGO_DIR="${1:-/tmp/ebc-pgo}"
WORKLOAD=(kernel-bench --n 4000 --d 32 --c 256 --threads 1,2,4)

if ! command -v llvm-profdata >/dev/null 2>&1; then
    # rustup's llvm-tools ships it under the toolchain lib dir
    TOOLS="$(rustc --print sysroot)/lib/rustlib/$(rustc -vV |
        sed -n 's/^host: //p')/bin"
    if [ -x "$TOOLS/llvm-profdata" ]; then
        PATH="$TOOLS:$PATH"
    else
        echo "error: llvm-profdata not found; rustup component add llvm-tools" >&2
        exit 1
    fi
fi

rm -rf "$PGO_DIR" && mkdir -p "$PGO_DIR"

echo "== stage 1: instrumented build"
RUSTFLAGS="-Cprofile-generate=$PGO_DIR" cargo build --release

echo "== stage 2: profiling run (${WORKLOAD[*]})"
./target/release/ebc-summarizer "${WORKLOAD[@]}"

echo "== stage 3: merge profiles"
llvm-profdata merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"

echo "== stage 4: optimized rebuild"
RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata" cargo build --release

echo "PGO binary ready: target/release/ebc-summarizer (profiles in $PGO_DIR)"
