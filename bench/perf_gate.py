#!/usr/bin/env python3
"""Perf-regression gate over ``BENCH_kernel.json`` (stdlib only).

Compares a freshly measured kernel sweep against a committed baseline
and fails when any comparable point regressed by more than the
threshold (default 15%). Two modes:

* ``speedup`` (default) — compares ``speedup_vs_scalar_st`` per
  (op, kernel, precision, threads) point. Each run's scalar-ST baseline
  is measured on the same host in the same process, so the ratio
  normalizes away absolute machine speed; this is the mode for
  heterogeneous CI runners.
* ``seconds`` — compares ``min_seconds`` directly. Only meaningful when
  baseline and candidate ran on the same hardware (e.g. a pinned perf
  box or a local PGO before/after).

Comparability rules:

* scalar rows (speedup == 1.0 by construction) are never gated;
* ``simd`` rows are skipped with a warning when the two files report
  different ``workload.simd_level`` values (an avx2 baseline says
  nothing about a neon runner);
* points present in only one file are reported but not gated (the
  sweep grid changed — that is a review question, not a regression).

Exit codes: 0 ok / bootstrap, 1 regression detected, 2 nothing was
comparable (both files parsed but no point could be gated — treat as a
configuration error, not a pass).

Bootstrap: a missing baseline file exits 0 with a notice, so the gate
can be wired into CI before the first genuine baseline is committed.
``--self-test`` runs the gate against synthetic in-memory documents —
including an artificially 2x-regressed candidate that MUST fail — and
needs no files at all.
"""

import argparse
import json
import os
import sys

DEFAULT_THRESHOLD = 0.15
GATED_KERNELS = ("blocked", "simd")


def key(p):
    return (p["op"], p["kernel"], p["precision"], int(p["threads"]))


def load(path):
    with open(path) as f:
        return json.load(f)


def simd_level(doc):
    return doc.get("workload", {}).get("simd_level", "unknown")


def compare(baseline, candidate, mode, threshold, out=sys.stdout):
    """Return (regressions, compared, skipped) over the two documents."""
    base = {key(p): p for p in baseline.get("points", [])}
    cand = {key(p): p for p in candidate.get("points", [])}
    levels = (simd_level(baseline), simd_level(candidate))
    level_mismatch = levels[0] != levels[1]

    regressions, compared, skipped = [], 0, 0
    for k in sorted(base):
        if k not in cand:
            print(f"note: {k} only in baseline (grid changed?)", file=out)
            continue
        op, kernel, precision, threads = k
        if kernel not in GATED_KERNELS:
            continue
        if kernel == "simd" and level_mismatch:
            skipped += 1
            print(
                f"skip: {k} — simd_level differs "
                f"(baseline={levels[0]}, candidate={levels[1]})",
                file=out)
            continue
        b, c = base[k], cand[k]
        if mode == "speedup":
            want, got = b["speedup_vs_scalar_st"], c["speedup_vs_scalar_st"]
            ok = got >= want * (1.0 - threshold)
            detail = f"speedup {want:.2f}x -> {got:.2f}x"
        else:
            want, got = b["min_seconds"], c["min_seconds"]
            ok = got <= want * (1.0 + threshold)
            detail = f"min_seconds {want:.3e} -> {got:.3e}"
        compared += 1
        if not ok:
            regressions.append((k, detail))
            print(f"REGRESSION: {k}: {detail} "
                  f"(threshold {threshold:.0%})", file=out)
    for k in sorted(set(cand) - set(base)):
        print(f"note: {k} only in candidate (not gated)", file=out)
    return regressions, compared, skipped


def synthetic_doc(level, scale):
    points = []
    for op in ("gains", "dist_col", "eval"):
        points.append(dict(op=op, kernel="scalar", precision="f32", threads=1,
                           mean_seconds=1.0, min_seconds=1.0,
                           speedup_vs_scalar_st=1.0, max_abs_dev=0.0))
        for kernel, base in (("blocked", 4.0), ("simd", 6.0)):
            for t in (1, 2):
                s = base * t * scale
                points.append(dict(op=op, kernel=kernel, precision="f32",
                                   threads=t, mean_seconds=1.0 / s,
                                   min_seconds=1.0 / s,
                                   speedup_vs_scalar_st=s, max_abs_dev=0.0))
    return {"workload": {"n": 1, "d": 1, "c": 1, "seed": 0,
                         "simd_level": level},
            "points": points}


def self_test(threshold):
    base = synthetic_doc("avx2", 1.0)

    clean, n, _ = compare(base, synthetic_doc("avx2", 1.0),
                          "speedup", threshold)
    assert not clean and n > 0, "clean candidate must pass"

    # 2x slower everywhere: every gated point must be flagged
    slow = synthetic_doc("avx2", 0.5)
    bad, n, _ = compare(base, slow, "speedup", threshold)
    assert len(bad) == n > 0, f"2x regression missed: {len(bad)}/{n}"
    bad, n, _ = compare(base, slow, "seconds", threshold)
    assert len(bad) == n > 0, "seconds mode missed the 2x regression"

    # a regression just inside the threshold must NOT be flagged
    near = synthetic_doc("avx2", 1.0 - threshold + 0.01)
    ok, _, _ = compare(base, near, "speedup", threshold)
    assert not ok, "within-threshold noise flagged as regression"

    # simd rows across different ISAs are skipped, blocked rows still gated
    neon = synthetic_doc("neon", 0.5)
    bad, n, skipped = compare(base, neon, "speedup", threshold)
    assert skipped > 0, "simd_level mismatch not skipped"
    assert all(k[1] == "blocked" for k, _ in bad), "skipped simd still gated"
    assert n > 0, "blocked rows must stay comparable across ISAs"

    print("self-test: all gate behaviors verified")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_kernel.json",
                    help="committed baseline document")
    ap.add_argument("--candidate", default="BENCH_kernel.new.json",
                    help="freshly measured document")
    ap.add_argument("--mode", choices=("speedup", "seconds"),
                    default="speedup")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="max tolerated relative regression (default 0.15)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate flags a synthetic 2x regression")
    args = ap.parse_args()

    if args.self_test:
        return self_test(args.threshold)

    if not os.path.exists(args.baseline):
        print(f"bootstrap: no baseline at {args.baseline} — nothing to "
              f"gate against; commit the candidate as the first baseline")
        return 0
    baseline, candidate = load(args.baseline), load(args.candidate)
    regressions, compared, skipped = compare(
        baseline, candidate, args.mode, args.threshold)
    print(f"compared {compared} point(s), skipped {skipped}, "
          f"{len(regressions)} regression(s) [mode={args.mode}, "
          f"threshold={args.threshold:.0%}]")
    if regressions:
        return 1
    if compared == 0:
        print("error: no comparable points — check the sweep grids and "
              "simd levels", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
