"""The artifact manifest: every (graph kind, impl, shape bucket, dtype)
variant that ``aot.py`` lowers to ``artifacts/*.hlo.txt`` and that the
Rust engine (rust/src/runtime/artifact.rs) loads at start-up.

Two kernel implementations are shipped for the batched graphs
(DESIGN.md §Perf / EXPERIMENTS.md §Perf):

* ``pallas`` — the L1 tiled work-matrix kernels (gains.py /
  work_matrix.py): the TPU-shaped realization of the paper's GPU
  algorithm. Under interpret=True the grid lowers to an XLA while-loop,
  which pays per-step dispatch overhead on the CPU PJRT backend — so
  these are the *architecture/compile-only* reference for real TPUs.
* ``jnp``   — the same work-matrix math as one fused matmul + reduction,
  which XLA-CPU vectorizes aggressively: the fast path on this testbed.

Buckets are chosen so every workload in the experiment index
(DESIGN.md §3) pads to a bucket with low waste:

* d=128   covers the paper's synthetic benchmarks (d=100, Fig. 2/Table 1)
* d=3584  covers the IMM melt-pressure cycles (d=3524, Fig. 3/Table 2/4)
* jnp eval_multi gets a fine (n, l) grid — padding waste directly
  multiplies runtime (the perf-pass lesson).

All pallas block sizes must divide their bucket (asserted below).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class Variant:
    kind: str                      # "gains" | "update" | "eval_multi"
    n: int                         # ground-set bucket
    d: int                         # feature-dim bucket
    dtype: str                     # "f32" | "bf16"
    impl: str = "pallas"           # "pallas" | "jnp"
    c: int = 0                     # gains: candidate bucket
    l: int = 0                     # eval_multi: set-count bucket
    k: int = 0                     # eval_multi: per-set slot bucket
    block_n: int = 512
    block_c: int = 256
    block_l: int = 0               # 0 = auto (fit ~4 MB of set tile)

    @property
    def name(self) -> str:
        if self.kind == "gains":
            core = f"n{self.n}_d{self.d}_c{self.c}"
        elif self.kind == "update":
            core = f"n{self.n}_d{self.d}"
        elif self.kind == "eval_multi":
            core = f"l{self.l}_k{self.k}_n{self.n}_d{self.d}"
        else:
            raise ValueError(self.kind)
        tag = "" if self.impl == "pallas" else f"_{self.impl}"
        return f"{self.kind}{tag}_{core}_{self.dtype}"

    @property
    def filename(self) -> str:
        return f"{self.name}.hlo.txt"

    def eff_block_n(self) -> int:
        return min(self.block_n, self.n)

    def eff_block_c(self) -> int:
        return min(self.block_c, self.c)

    def eff_block_l(self) -> int:
        """Auto block_l: as many sets per program as fit ~4 MB of tile."""
        if self.block_l:
            return min(self.block_l, self.l)
        dt = 4 if self.dtype == "f32" else 2
        per_set = max(self.k * self.d * dt, 1)
        bl = max(1, (4 << 20) // per_set)
        # largest divisor of l that is <= bl
        best = 1
        for cand in range(1, self.l + 1):
            if self.l % cand == 0 and cand <= bl:
                best = cand
        return best

    def validate(self):
        assert self.dtype in ("f32", "bf16"), self.dtype
        assert self.impl in ("pallas", "jnp"), self.impl
        if self.impl == "jnp" or self.kind == "update":
            return
        assert self.n % self.eff_block_n() == 0, (self.n, self.eff_block_n())
        if self.kind == "gains":
            assert self.c % self.eff_block_c() == 0, (self.c, self.eff_block_c())
        if self.kind == "eval_multi":
            assert self.l % self.eff_block_l() == 0, (self.l, self.eff_block_l())
            assert self.k > 0


def _both_dtypes(**kw):
    return [Variant(dtype="f32", **kw), Variant(dtype="bf16", **kw)]


def default_manifest():
    """The standard bucket set (built by ``make artifacts``)."""
    out = []
    # ---- gains: greedy hot path ----------------------------------------
    # jnp fast path: fine n grid
    for n in [1024, 2048, 4096, 8192, 16384]:
        for c in [256, 1024]:
            if c > n:
                continue
            out += _both_dtypes(kind="gains", impl="jnp", n=n, d=128, c=c)
    out += _both_dtypes(kind="gains", impl="jnp", n=1024, d=3584, c=256)
    out += _both_dtypes(kind="gains", impl="jnp", n=1024, d=3584, c=1024)
    # pallas reference buckets (TPU-shaped; compile-only on real HW)
    for n, d, c in [(1024, 128, 256), (4096, 128, 1024), (1024, 3584, 256)]:
        out += _both_dtypes(kind="gains", impl="pallas", n=n, d=d, c=c)
    # ---- update: post-selection state refresh (always pure jnp) ---------
    for n, d in [(1024, 128), (2048, 128), (4096, 128), (8192, 128),
                 (16384, 128), (1024, 3584)]:
        out += _both_dtypes(kind="update", impl="jnp", n=n, d=d)
    # ---- eval_multi: sieve-family + Fig. 2 multi-set workloads ----------
    # jnp fast path: fine (n, l, k) grid — padding waste multiplies runtime
    for n in [1024, 2048, 4096, 8192, 16384]:
        for l in [8, 16, 32, 64, 128, 256]:
            out += _both_dtypes(kind="eval_multi", impl="jnp", n=n, d=128, l=l, k=16)
    for n in [1024, 2048, 4096]:
        for l in [16, 32, 64]:
            out += _both_dtypes(kind="eval_multi", impl="jnp", n=n, d=128, l=l, k=32)
        for l in [32, 64]:
            out += _both_dtypes(kind="eval_multi", impl="jnp", n=n, d=128, l=l, k=64)
    out += _both_dtypes(kind="eval_multi", impl="jnp", n=1024, d=3584, l=64, k=16)
    # pallas reference buckets
    for l, k, n, d in [(64, 16, 1024, 128), (256, 16, 4096, 128),
                       (64, 64, 4096, 128), (64, 16, 1024, 3584)]:
        out += _both_dtypes(kind="eval_multi", impl="pallas", n=n, d=d, l=l, k=k)
    for v in out:
        v.validate()
    names = [v.name for v in out]
    assert len(names) == len(set(names)), "duplicate variant names"
    return out


def full_manifest():
    """Extended buckets for the --full benchmark sweeps."""
    out = default_manifest()
    for n in [32768]:
        out += _both_dtypes(kind="gains", impl="jnp", n=n, d=128, c=1024)
        out += _both_dtypes(kind="update", impl="jnp", n=n, d=128)
        for l in [64, 256]:
            out += _both_dtypes(kind="eval_multi", impl="jnp", n=n, d=128, l=l, k=16)
    out += _both_dtypes(kind="gains", impl="jnp", n=4096, d=3584, c=1024)
    out += _both_dtypes(kind="update", impl="jnp", n=4096, d=3584)
    out += _both_dtypes(kind="eval_multi", impl="jnp", n=4096, d=128, l=64, k=512)
    for v in out:
        v.validate()
    return out
