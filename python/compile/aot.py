"""AOT pipeline: lower every manifest variant to HLO **text** and write
``artifacts/<name>.hlo.txt`` + ``artifacts/manifest.json``.

Interchange format is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Graphs are lowered with ``return_tuple=True``; the Rust runtime unwraps
tuples (rust/src/runtime/executable.rs).

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--full] [--only REGEX]
    python -m compile.aot --report          # VMEM/MXU estimates only
"""

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import manifest as mf
from . import model
from .kernels import gains as gains_kernel
from .kernels import work_matrix as wm_kernel

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_variant(v: mf.Variant):
    """Build + lower one variant; returns (hlo_text, input_names)."""
    if v.kind == "gains":
        if v.impl == "jnp":
            fn = model.make_gains_jnp(v.dtype)
        else:
            fn = model.make_gains(v.dtype, block_n=v.eff_block_n(),
                                  block_c=v.eff_block_c())
        args = [_spec((v.n, v.d)), _spec((v.n,)), _spec((v.n,)),
                _spec((v.n,)), _spec((v.c, v.d)), _spec((v.c,))]
        inputs = ["v", "vsq", "vmask", "mindist", "c", "cmask"]
    elif v.kind == "update":
        fn = model.make_update(v.dtype)
        args = [_spec((v.n, v.d)), _spec((v.n,)), _spec((v.n,)),
                _spec((v.n,)), _spec((v.d,))]
        inputs = ["v", "vsq", "vmask", "mindist", "s"]
    elif v.kind == "eval_multi":
        if v.impl == "jnp":
            fn = model.make_eval_multi_jnp(v.l, v.dtype)
        else:
            fn = model.make_eval_multi(v.l, v.dtype, block_n=v.eff_block_n(),
                                       block_l=v.eff_block_l())
        args = [_spec((v.n, v.d)), _spec((v.n,)), _spec((v.n,)),
                _spec((v.l * v.k, v.d)), _spec((v.l * v.k,))]
        inputs = ["v", "vsq", "vmask", "s_flat", "smask_flat"]
    else:
        raise ValueError(v.kind)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), inputs


def variant_report(v: mf.Variant) -> dict:
    """Static perf estimates recorded into the manifest (DESIGN.md §Perf)."""
    dt_bytes = 4 if v.dtype == "f32" else 2
    if v.kind == "gains":
        bn, bc = v.eff_block_n(), v.eff_block_c()
        flops = gains_kernel.mxu_flops(v.n, v.c, v.d)
        if v.impl == "jnp":
            vmem = (v.n + v.c) * v.d * dt_bytes + v.n * v.c * 4
            grid = 1
        else:
            vmem = gains_kernel.vmem_bytes(bn, bc, v.d, dt_bytes)
            grid = (v.n // bn) * (v.c // bc)
    elif v.kind == "eval_multi":
        bn, bl = v.eff_block_n(), v.eff_block_l()
        flops = 2.0 * v.n * v.l * v.k * v.d
        if v.impl == "jnp":
            vmem = (v.n + v.l * v.k) * v.d * dt_bytes + v.n * v.l * v.k * 4
            grid = 1
        else:
            vmem = wm_kernel.vmem_bytes(bn, bl, v.k, v.d, dt_bytes)
            grid = (v.n // bn) * (v.l // bl)
    else:  # update: one matvec
        vmem = v.n * v.d * dt_bytes + 4 * v.n * 4
        flops = 2.0 * v.n * v.d
        grid = 1
    return {
        "vmem_bytes": int(vmem),
        "mxu_flops": float(flops),
        "grid_programs": int(grid),
        # MXU utilization proxy: fraction of an aligned 128x128xd tile the
        # matmul occupies (1.0 when all dims are multiples of 128).
        "mxu_alignment": _mxu_alignment(v),
    }


def _mxu_alignment(v: mf.Variant) -> float:
    def frac(x, q=128):
        return x / (((x + q - 1) // q) * q)
    if v.kind == "gains":
        return frac(v.eff_block_n()) * frac(v.eff_block_c()) * frac(v.d)
    if v.kind == "eval_multi":
        return frac(v.eff_block_n()) * frac(v.eff_block_l() * v.k) * frac(v.d)
    return frac(v.d)


def entry_dict(v: mf.Variant, inputs, report, elapsed_s):
    return {
        "name": v.name,
        "file": v.filename,
        "kind": v.kind,
        "impl": v.impl,
        "dtype": v.dtype,
        "n": v.n,
        "d": v.d,
        "c": v.c,
        "l": v.l,
        "k": v.k,
        "block_n": v.eff_block_n(),
        "block_c": v.eff_block_c() if v.kind == "gains" else 0,
        "block_l": v.eff_block_l() if v.kind == "eval_multi" else 0,
        "inputs": inputs,
        "lower_seconds": round(elapsed_s, 3),
        **report,
    }


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--full", action="store_true",
                   help="extended bucket set for --full benchmark sweeps")
    p.add_argument("--only", default=None,
                   help="regex filter on variant names")
    p.add_argument("--report", action="store_true",
                   help="print VMEM/MXU estimates and exit (no lowering)")
    args = p.parse_args(argv)

    variants = mf.full_manifest() if args.full else mf.default_manifest()
    if args.only:
        rx = re.compile(args.only)
        variants = [v for v in variants if rx.search(v.name)]
    if not variants:
        print("no variants match", file=sys.stderr)
        return 1

    if args.report:
        hdr = f"{'variant':44s} {'vmem':>10s} {'programs':>9s} {'GFLOP':>9s} {'mxu_align':>9s}"
        print(hdr)
        for v in variants:
            r = variant_report(v)
            print(f"{v.name:44s} {r['vmem_bytes']/1e6:8.2f}MB "
                  f"{r['grid_programs']:9d} {r['mxu_flops']/1e9:9.3f} "
                  f"{r['mxu_alignment']:9.3f}")
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []
    for v in variants:
        t0 = time.time()
        text, inputs = lower_variant(v)
        path = os.path.join(args.out_dir, v.filename)
        with open(path, "w") as f:
            f.write(text)
        dt = time.time() - t0
        entries.append(entry_dict(v, inputs, variant_report(v), dt))
        print(f"  lowered {v.name:44s} {len(text)/1e3:8.1f} kB  {dt:5.1f}s")

    man = {"version": MANIFEST_VERSION, "entries": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
