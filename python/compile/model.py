"""L2: the JAX compute graphs the Rust coordinator executes via PJRT.

Three graph families, each calling the L1 Pallas kernels so that kernel
and reduction lower into one HLO module (single executable per variant):

* ``make_gains``      -- batched greedy marginal gains (kernel: gains.py)
* ``make_update``     -- post-selection mindist update + new f value
* ``make_eval_multi`` -- multi-set work-matrix evaluation (kernel:
                         work_matrix.py)

All module *inputs and outputs are f32*; for the reduced-precision
("FP16") variants the graph casts V/C/S to bfloat16 before the kernel's
MXU matmul and accumulates in f32. Keeping the interface f32 keeps the
Rust Literal handling uniform; the transfer-bandwidth half of the paper's
FP16 win is modeled analytically in rust/src/gpumodel (DESIGN.md §4).

Every function returns a tuple (lowered with return_tuple=True) — the
Rust side unwraps with ``to_tuple1``/``to_tuple``.
"""

import jax
import jax.numpy as jnp

from .kernels import gains as gains_kernel
from .kernels import work_matrix as wm_kernel

BIG = 1e30


def _cast(x, dtype):
    return x if dtype == "f32" else x.astype(jnp.bfloat16)


def make_gains(dtype="f32", block_n=None, block_c=None):
    """Graph: (v, vsq, vmask, mindist, c, cmask) -> (gains,).

    gains[j] = Δf(c_j | S) in f32; masked candidates get -BIG.
    """
    kw = {}
    if block_n is not None:
        kw["block_n"] = block_n
    if block_c is not None:
        kw["block_c"] = block_c

    def gains_fn(v, vsq, vmask, mindist, c, cmask):
        vc = _cast(v, dtype)
        cc_ = _cast(c, dtype)
        csq = jnp.sum(c * c, axis=1)  # f32; candidates change per call
        partials = gains_kernel.gains_partials(vc, vsq, vmask, mindist,
                                               cc_, csq, **kw)
        g = jnp.sum(partials, axis=0) / jnp.sum(vmask)
        g = g * cmask - (1.0 - cmask) * BIG
        return (g,)

    return gains_fn


def make_update(dtype="f32"):
    """Graph: (v, vsq, vmask, mindist, s) -> (new_mindist, f_value).

    Pure-jnp L2 (one matvec + elementwise min — no tiling win); the
    mindist buffer is donated at lowering time (aot.py).
    """

    def update_fn(v, vsq, vmask, mindist, s):
        vc = _cast(v, dtype)
        sc = _cast(s, dtype)
        cross = (vc @ sc).astype(jnp.float32)
        d2 = jnp.maximum(vsq - 2.0 * cross + jnp.sum(s * s), 0.0)
        nm = jnp.minimum(mindist, d2)
        f = jnp.sum(vmask * (vsq - nm)) / jnp.sum(vmask)
        return (nm, f)

    return update_fn


def make_gains_jnp(dtype="f32"):
    """Pure-jnp variant of ``make_gains`` — the whole work matrix as one
    XLA-fusable matmul + reductions (no Pallas grid).

    Rationale (EXPERIMENTS.md §Perf): interpret-mode Pallas lowers the
    grid to an XLA while-loop of dynamic-slices, which the CPU backend
    executes with per-step dispatch overhead. The jnp formulation is the
    *same math* (it IS the paper's work matrix) and is what a fused
    device kernel achieves; on real TPU hardware the Pallas variant is
    the one to compile. Both are shipped; the engine selects per config.
    """

    def gains_fn(v, vsq, vmask, mindist, c, cmask):
        vc = _cast(v, dtype)
        cc_ = _cast(c, dtype)
        csq = jnp.sum(c * c, axis=1)
        cross = jax.lax.dot_general(
            vc, cc_, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (N, C)
        d2 = jnp.maximum(vsq[:, None] + csq[None, :] - 2.0 * cross, 0.0)
        red = jnp.maximum(mindist[:, None] - d2, 0.0) * vmask[:, None]
        g = jnp.sum(red, axis=0) / jnp.sum(vmask)
        return (g * cmask - (1.0 - cmask) * BIG,)

    return gains_fn


def make_eval_multi_jnp(num_sets, dtype="f32"):
    """Pure-jnp variant of ``make_eval_multi`` (see make_gains_jnp)."""

    def eval_multi_fn(v, vsq, vmask, s_flat, smask_flat):
        vc = _cast(v, dtype)
        sf = _cast(s_flat, dtype)
        ssq = jnp.sum(s_flat * s_flat, axis=1)
        cross = jax.lax.dot_general(
            vc, sf, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (N, l*k)
        d2 = jnp.maximum(vsq[:, None] + ssq[None, :] - 2.0 * cross, 0.0)
        d2 = d2 + (1.0 - smask_flat)[None, :] * BIG
        n = v.shape[0]
        k = s_flat.shape[0] // num_sets
        m = jnp.min(d2.reshape(n, num_sets, k), axis=2)
        m = jnp.minimum(m, vsq[:, None])
        contrib = vmask[:, None] * (vsq[:, None] - m)
        f = jnp.sum(contrib, axis=0) / jnp.sum(vmask)
        return (f,)

    return eval_multi_fn


def make_eval_multi(num_sets, dtype="f32", block_n=None, block_l=None):
    """Graph: (v, vsq, vmask, s_flat, smask_flat) -> (f_values,).

    f_values: (l,) f32 — EBC value of each packed set (paper Alg. 2 +
    the W·1 reduce).
    """
    kw = {}
    if block_n is not None:
        kw["block_n"] = block_n
    if block_l is not None:
        kw["block_l"] = block_l

    def eval_multi_fn(v, vsq, vmask, s_flat, smask_flat):
        vc = _cast(v, dtype)
        sf = _cast(s_flat, dtype)
        ssq = jnp.sum(s_flat * s_flat, axis=1)
        partials = wm_kernel.work_matrix_partials(
            vc, vsq, vmask, sf, ssq, smask_flat, num_sets, **kw)
        f = jnp.sum(partials, axis=0) / jnp.sum(vmask)
        return (f,)

    return eval_multi_fn
