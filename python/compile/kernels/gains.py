"""L1 Pallas kernel: batched greedy marginal gains.

This is the TPU-style realisation of the paper's GPU algorithm (§4.2)
specialised to the Greedy optimizer's evaluation pattern
``S_multi = {S ∪ {c_1}, ..., S ∪ {c_m}}``: because every set shares the
prefix ``S``, its contribution is carried by the per-ground-vector state
``mindist`` and each cell of the work matrix reduces to

    W[j, i] = max(mindist_i - d²(v_i, c_j), 0) * vmask_i / |V|

Hardware mapping (cf. DESIGN.md §Hardware-Adaptation):

* the CUDA block's shared-memory tile of ``V`` becomes a ``(bn, d)``
  BlockSpec that stages the ground tile into VMEM once per grid row;
* the per-thread scalar distance loop becomes one MXU matmul
  ``Vtile @ Ctileᵀ`` (compute dtype f32 or bf16, f32 accumulation);
* the coalesced global-memory layout of ``S_multi`` becomes the dense
  candidate tile ``(bc, d)``, staged per grid column;
* the row-reduce ``W·1`` is fused: each program emits the partial
  column-sum of its tile, and the surrounding L2 graph adds the
  ``grid_n`` partials.

Grid: ``(N/bn, C/bc)``; output partials: ``(N/bn, C)`` f32.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_C = 128


def _gains_kernel(v_ref, vsq_ref, vmask_ref, mind_ref, c_ref, csq_ref, out_ref):
    """One (bn, bc) tile of the work matrix, reduced over bn.

    All refs live in VMEM. ``v_ref``/``c_ref`` carry the compute dtype;
    every reduction happens in f32.
    """
    v = v_ref[...]                         # (bn, d)  compute dtype
    c = c_ref[...]                         # (bc, d)  compute dtype
    # Cross term on the MXU: (bn, d) x (bc, d)^T with f32 accumulation.
    cross = jax.lax.dot_general(
        v, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                      # (bn, bc) f32
    vsq = vsq_ref[...]                     # (bn,)  f32
    csq = csq_ref[...]                     # (bc,)  f32
    d2 = jnp.maximum(vsq[:, None] + csq[None, :] - 2.0 * cross, 0.0)
    mind = mind_ref[...]                   # (bn,)  f32
    vmask = vmask_ref[...]                 # (bn,)  f32
    red = jnp.maximum(mind[:, None] - d2, 0.0) * vmask[:, None]
    out_ref[...] = jnp.sum(red, axis=0, keepdims=True)  # (1, bc) f32


@functools.partial(jax.jit, static_argnames=("block_n", "block_c"))
def gains_partials(v, vsq, vmask, mindist, c, csq,
                   block_n=DEFAULT_BLOCK_N, block_c=DEFAULT_BLOCK_C):
    """Run the tiled kernel; returns per-row-block partial sums (N/bn, C).

    v: (N, d) compute dtype; c: (C, d) compute dtype; all vectors f32.
    N must be a multiple of block_n and C of block_c (the Rust engine's
    bucket/padding policy guarantees this; see rust/src/engine/tiling.rs).
    """
    n, d = v.shape
    cc = c.shape[0]
    bn = min(block_n, n)
    bc = min(block_c, cc)
    assert n % bn == 0 and cc % bc == 0, (n, cc, bn, bc)
    grid = (n // bn, cc // bc)
    return pl.pallas_call(
        _gains_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),   # V tile ("shared mem")
            pl.BlockSpec((bn,), lambda i, j: (i,)),       # vsq
            pl.BlockSpec((bn,), lambda i, j: (i,)),       # vmask
            pl.BlockSpec((bn,), lambda i, j: (i,)),       # mindist
            pl.BlockSpec((bc, d), lambda i, j: (j, 0)),   # candidate tile
            pl.BlockSpec((bc,), lambda i, j: (j,)),       # csq
        ],
        out_specs=pl.BlockSpec((1, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0], cc), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(v, vsq, vmask, mindist, c, csq)


def vmem_bytes(block_n, block_c, d, dtype_bytes):
    """VMEM footprint estimate of one program instance (DESIGN.md §Perf)."""
    v_tile = block_n * d * dtype_bytes
    c_tile = block_c * d * dtype_bytes
    vecs = (3 * block_n + block_c) * 4
    acc = block_n * block_c * 4  # d2/red tile, f32
    out = block_c * 4
    return v_tile + c_tile + vecs + acc + out


def mxu_flops(n, c, d):
    """MXU FLOPs of the cross-term matmul for a full (N, C) evaluation."""
    return 2.0 * n * c * d
