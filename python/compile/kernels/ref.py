"""Pure-jnp reference oracle for the EBC kernels.

This is the correctness ground truth for the Pallas kernels in
``work_matrix.py`` and ``gains.py``: pytest (``python/tests``) asserts
``assert_allclose`` between each kernel and the functions here across a
hypothesis sweep of shapes and dtypes.

Conventions (shared with the Rust engine, see rust/src/engine/):

* ``v``       -- ground set, shape ``(N, d)``; padded rows are arbitrary but
                 masked by ``vmask``.
* ``vsq``     -- ``|v_i|^2`` precomputed per dataset, shape ``(N,)``, f32.
                 This doubles as the distance to the auxiliary exemplar
                 ``e0 = 0`` of the EBC definition (paper eq. 4).
* ``vmask``   -- 1.0 for real rows, 0.0 for padding, shape ``(N,)``.
* ``mindist`` -- current min squared distance of every ground vector to
                 ``S ∪ {e0}``; initialised to ``vsq`` (distance to e0).
* squared Euclidean distance throughout (paper §5).

All reductions are performed in f32 regardless of the compute dtype.
"""

import jax.numpy as jnp

BIG = 1e30  # sentinel for masked candidates / set slots


def pairwise_sqdist(a, b):
    """Squared Euclidean distances, shape (n, m), for a:(n,d) b:(m,d)."""
    an = jnp.sum(a * a, axis=1, keepdims=True)
    bn = jnp.sum(b * b, axis=1, keepdims=True).T
    d2 = an + bn - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def ebc_value_ref(v, vmask, s, smask):
    """Direct EBC function value f(S) = L({e0}) - L(S ∪ {e0}) (paper eq. 4).

    v: (N, d), vmask: (N,), s: (k, d), smask: (k,).
    """
    vsq = jnp.sum(v * v, axis=1)
    d2 = pairwise_sqdist(v, s) + (1.0 - smask)[None, :] * BIG
    m = jnp.minimum(jnp.min(d2, axis=1), vsq)  # include e0
    n = jnp.sum(vmask)
    return jnp.sum(vmask * (vsq - m)) / n


def ebc_gains_ref(v, vsq, vmask, mindist, c, cmask):
    """Marginal gains Δf(c_j | S) for a batch of candidates.

    Δf(c | S) = mean_i max(mindist_i - d²(v_i, c), 0)  -- the batched
    greedy step. Masked candidates get -BIG so they never win argmax.

    v: (N, d), c: (C, d); returns (C,) f32.
    """
    d2 = pairwise_sqdist(v, c)
    red = jnp.maximum(mindist[:, None] - d2, 0.0) * vmask[:, None]
    gains = jnp.sum(red, axis=0) / jnp.sum(vmask)
    return gains * cmask - (1.0 - cmask) * BIG


def ebc_update_ref(v, vsq, vmask, mindist, s):
    """After selecting exemplar ``s``: new mindist and the new f(S) value.

    s: (d,). Returns (new_mindist (N,), f_value scalar).
    """
    d2 = jnp.maximum(vsq - 2.0 * (v @ s) + jnp.sum(s * s), 0.0)
    nm = jnp.minimum(mindist, d2)
    f = jnp.sum(vmask * (vsq - nm)) / jnp.sum(vmask)
    return nm, f


def ebc_eval_multi_ref(v, vsq, vmask, s_flat, smask_flat, num_sets):
    """The paper's work-matrix evaluation (Alg. 2): f(S_j) for l sets at once.

    s_flat: (l*k, d) -- the single dense "evaluation set matrix" S of the
    paper's memory layout (§4.2); smask_flat: (l*k,) marks real slots.
    Returns (l,) f32 of EBC function values.
    """
    l = num_sets
    k = s_flat.shape[0] // l
    d2 = pairwise_sqdist(v, s_flat) + (1.0 - smask_flat)[None, :] * BIG
    d2 = d2.reshape(v.shape[0], l, k)
    m = jnp.min(d2, axis=2)                      # (N, l) min over set slots
    m = jnp.minimum(m, vsq[:, None])             # include e0
    contrib = vmask[:, None] * (vsq[:, None] - m)
    return jnp.sum(contrib, axis=0) / jnp.sum(vmask)
