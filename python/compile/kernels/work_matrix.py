"""L1 Pallas kernel: the paper's work matrix W for arbitrary multi-set
evaluation (Algorithm 2).

Unlike ``gains.py`` (which exploits the shared-prefix structure of the
Greedy step), this kernel evaluates *arbitrary* sets
``S_multi = {S_1, ..., S_l}``, each with up to ``k`` members — the
evaluation pattern of the sieve-family optimizers (SieveStreaming,
SieveStreaming++, ThreeSieves) and of the paper's Fig. 2 benchmark.

Memory layout follows the paper §4.2 "Memory Layout": all sets are packed
into one dense evaluation-set matrix ``S ∈ ((l·k), d)`` with a slot mask
for ragged sets (the paper leaves unused entries "simply empty"; we mask
them with +BIG so they never win the min). The matrix is transferred from
the Rust coordinator in a single Literal per call.

Each grid program computes a ``(bn, bl)`` tile of W:

    W[j, i] = vmask_i * (vsq_i - min(vsq_i, min_{s ∈ S_j} d²(v_i, s))) / |V|

(the e0 column of the EBC definition is folded in via ``vsq``), reduced
over the ``bn`` ground rows into a partial ``(1, bl)`` f32 row. The L2
graph sums the ``N/bn`` partials — the paper's ``W · 1`` reduce.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30

DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_L = 8


def _work_matrix_kernel(v_ref, vsq_ref, vmask_ref, s_ref, ssq_ref,
                        smask_ref, out_ref, *, k):
    v = v_ref[...]                          # (bn, d) compute dtype
    s = s_ref[...]                          # (bl*k, d) compute dtype
    cross = jax.lax.dot_general(
        v, s,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                       # (bn, bl*k) f32
    vsq = vsq_ref[...]                      # (bn,) f32
    ssq = ssq_ref[...]                      # (bl*k,) f32
    smask = smask_ref[...]                  # (bl*k,) f32
    d2 = jnp.maximum(vsq[:, None] + ssq[None, :] - 2.0 * cross, 0.0)
    d2 = d2 + (1.0 - smask)[None, :] * BIG  # empty slots never win the min
    bn = d2.shape[0]
    bl = d2.shape[1] // k
    m = jnp.min(d2.reshape(bn, bl, k), axis=2)   # (bn, bl)
    m = jnp.minimum(m, vsq[:, None])             # e0 column
    vmask = vmask_ref[...]
    contrib = vmask[:, None] * (vsq[:, None] - m)
    out_ref[...] = jnp.sum(contrib, axis=0, keepdims=True)  # (1, bl)


@functools.partial(jax.jit, static_argnames=("num_sets", "block_n", "block_l"))
def work_matrix_partials(v, vsq, vmask, s_flat, ssq, smask, num_sets,
                         block_n=DEFAULT_BLOCK_N, block_l=DEFAULT_BLOCK_L):
    """Partial f-value sums, shape (N/bn, l) f32.

    s_flat: (l*k, d) packed evaluation-set matrix; ssq/smask: (l*k,) f32.
    N % block_n == 0 and l % block_l == 0 (engine padding guarantees it).
    """
    n, d = v.shape
    lk = s_flat.shape[0]
    assert lk % num_sets == 0, (lk, num_sets)
    k = lk // num_sets
    bn = min(block_n, n)
    bl = min(block_l, num_sets)
    assert n % bn == 0 and num_sets % bl == 0, (n, num_sets, bn, bl)
    grid = (n // bn, num_sets // bl)
    kern = functools.partial(_work_matrix_kernel, k=k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),       # V tile
            pl.BlockSpec((bn,), lambda i, j: (i,)),           # vsq
            pl.BlockSpec((bn,), lambda i, j: (i,)),           # vmask
            pl.BlockSpec((bl * k, d), lambda i, j: (j, 0)),   # set tile
            pl.BlockSpec((bl * k,), lambda i, j: (j,)),       # ssq
            pl.BlockSpec((bl * k,), lambda i, j: (j,)),       # smask
        ],
        out_specs=pl.BlockSpec((1, bl), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((grid[0], num_sets), jnp.float32),
        interpret=True,
    )(v, vsq, vmask, s_flat, ssq, smask)


def vmem_bytes(block_n, block_l, k, d, dtype_bytes):
    """VMEM footprint estimate of one program instance."""
    v_tile = block_n * d * dtype_bytes
    s_tile = block_l * k * d * dtype_bytes
    vecs = 2 * block_n * 4 + 2 * block_l * k * 4
    acc = block_n * block_l * k * 4
    return v_tile + s_tile + vecs + acc
