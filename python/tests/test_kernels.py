"""L1 Pallas kernels vs the pure-jnp oracle: hypothesis sweeps over
shapes, block sizes, masks and dtypes (the core correctness signal of the
compile path)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gains as gains_kernel
from compile.kernels import ref
from compile.kernels import work_matrix as wm_kernel

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def make_problem(rng, n, d, c):
    v = rng.normal(size=(n, d)).astype(np.float32)
    vsq = (v * v).sum(1).astype(np.float32)
    vmask = np.ones(n, np.float32)
    pad = rng.integers(0, max(n // 4, 1))
    if pad:
        vmask[n - pad:] = 0.0
    mindist = (vsq * rng.uniform(0.3, 1.0, size=n)).astype(np.float32)
    cands = rng.normal(size=(c, d)).astype(np.float32)
    cmask = np.ones(c, np.float32)
    cpad = rng.integers(0, max(c // 4, 1))
    if cpad:
        cmask[c - cpad:] = 0.0
    return v, vsq, vmask, mindist, cands, cmask


@settings(**SETTINGS)
@given(
    st.sampled_from([32, 64, 96, 128]),   # n
    st.sampled_from([4, 16, 100]),        # d
    st.sampled_from([8, 16, 32]),         # c
    st.sampled_from([16, 32]),            # block_n
    st.sampled_from([8, 16]),             # block_c
    st.integers(0, 2**31 - 1),
)
def test_gains_kernel_matches_ref(n, d, c, bn, bc, seed):
    if n % bn or c % bc:
        return
    rng = np.random.default_rng(seed)
    v, vsq, vmask, mindist, cands, cmask = make_problem(rng, n, d, c)
    csq = (cands * cands).sum(1)
    partials = gains_kernel.gains_partials(
        jnp.array(v), jnp.array(vsq), jnp.array(vmask), jnp.array(mindist),
        jnp.array(cands), jnp.array(csq), block_n=bn, block_c=bc)
    got = np.asarray(partials).sum(0) / vmask.sum()
    want = np.asarray(ref.ebc_gains_ref(
        jnp.array(v), jnp.array(vsq), jnp.array(vmask), jnp.array(mindist),
        jnp.array(cands), jnp.ones(c)))
    # compare unmasked gains (ref applies cmask; kernel doesn't)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    st.sampled_from([32, 64, 128]),       # n
    st.sampled_from([4, 16, 64]),         # d
    st.sampled_from([4, 8, 16]),          # l
    st.sampled_from([2, 4, 8]),           # k
    st.integers(0, 2**31 - 1),
)
def test_work_matrix_kernel_matches_ref(n, d, l, k, seed):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(n, d)).astype(np.float32)
    vsq = (v * v).sum(1).astype(np.float32)
    vmask = np.ones(n, np.float32)
    s_flat = rng.normal(size=(l * k, d)).astype(np.float32)
    smask = (rng.uniform(size=l * k) > 0.3).astype(np.float32)
    ssq = (s_flat * s_flat).sum(1).astype(np.float32)
    partials = wm_kernel.work_matrix_partials(
        jnp.array(v), jnp.array(vsq), jnp.array(vmask),
        jnp.array(s_flat), jnp.array(ssq), jnp.array(smask),
        num_sets=l, block_n=32, block_l=min(4, l))
    got = np.asarray(partials).sum(0) / vmask.sum()
    want = np.asarray(ref.ebc_eval_multi_ref(
        jnp.array(v), jnp.array(vsq), jnp.array(vmask),
        jnp.array(s_flat), jnp.array(smask), l))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_gains_kernel_respects_vmask():
    rng = np.random.default_rng(0)
    n, d, c = 64, 10, 8
    v, vsq, vmask, mindist, cands, _ = make_problem(rng, n, d, c)
    vmask = np.ones(n, np.float32)
    vmask[32:] = 0.0
    csq = (cands * cands).sum(1)
    # kernel on the full array with mask == ref on the sliced array
    partials = gains_kernel.gains_partials(
        jnp.array(v), jnp.array(vsq), jnp.array(vmask), jnp.array(mindist),
        jnp.array(cands), jnp.array(csq), block_n=32, block_c=8)
    got = np.asarray(partials).sum(0) / 32.0
    want = np.asarray(ref.ebc_gains_ref(
        jnp.array(v[:32]), jnp.array(vsq[:32]), jnp.ones(32),
        jnp.array(mindist[:32]), jnp.array(cands), jnp.ones(c)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_work_matrix_empty_set_value_zero():
    rng = np.random.default_rng(1)
    n, d, l, k = 32, 6, 4, 3
    v = rng.normal(size=(n, d)).astype(np.float32)
    vsq = (v * v).sum(1).astype(np.float32)
    s_flat = rng.normal(size=(l * k, d)).astype(np.float32)
    smask = np.zeros(l * k, np.float32)  # all slots empty
    ssq = (s_flat * s_flat).sum(1).astype(np.float32)
    partials = wm_kernel.work_matrix_partials(
        jnp.array(v), jnp.array(vsq), jnp.ones(n),
        jnp.array(s_flat), jnp.array(ssq), jnp.array(smask),
        num_sets=l, block_n=32, block_l=4)
    got = np.asarray(partials).sum(0) / n
    np.testing.assert_allclose(got, np.zeros(l), atol=1e-5)


def test_bf16_model_close_to_f32():
    rng = np.random.default_rng(2)
    n, d, c = 128, 100, 16
    v, vsq, vmask, mindist, cands, cmask = make_problem(rng, n, d, c)
    args = (jnp.array(v), jnp.array(vsq), jnp.array(vmask),
            jnp.array(mindist), jnp.array(cands), jnp.array(cmask))
    g32 = np.asarray(model.make_gains("f32")(*args)[0])
    g16 = np.asarray(model.make_gains("bf16")(*args)[0])
    real = cmask > 0
    np.testing.assert_allclose(g16[real], g32[real], rtol=3e-2, atol=3e-2)


def test_jnp_variants_match_pallas_variants():
    """The two shipped kernel impls (DESIGN.md §Perf) are numerically
    interchangeable."""
    rng = np.random.default_rng(5)
    n, d, c = 128, 100, 16
    v, vsq, vmask, mindist, cands, cmask = make_problem(rng, n, d, c)
    args = (jnp.array(v), jnp.array(vsq), jnp.array(vmask),
            jnp.array(mindist), jnp.array(cands), jnp.array(cmask))
    g_pallas = np.asarray(model.make_gains("f32", block_n=64, block_c=16)(*args)[0])
    g_jnp = np.asarray(model.make_gains_jnp("f32")(*args)[0])
    real = cmask > 0
    np.testing.assert_allclose(g_pallas[real], g_jnp[real], rtol=1e-5, atol=1e-5)

    l, k = 8, 4
    s_flat = rng.normal(size=(l * k, d)).astype(np.float32)
    smask = (rng.uniform(size=l * k) > 0.2).astype(np.float32)
    eargs = (jnp.array(v), jnp.array(vsq), jnp.array(vmask),
             jnp.array(s_flat), jnp.array(smask))
    e_pallas = np.asarray(model.make_eval_multi(l, "f32", block_n=64, block_l=4)(*eargs)[0])
    e_jnp = np.asarray(model.make_eval_multi_jnp(l, "f32")(*eargs)[0])
    np.testing.assert_allclose(e_pallas, e_jnp, rtol=1e-5, atol=1e-5)


def test_vmem_estimates_positive():
    assert gains_kernel.vmem_bytes(256, 128, 128, 4) > 0
    assert wm_kernel.vmem_bytes(256, 8, 16, 128, 2) > 0
    assert gains_kernel.mxu_flops(1024, 256, 128) == 2.0 * 1024 * 256 * 128
