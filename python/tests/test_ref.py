"""Correctness of the pure-jnp oracle itself against numpy brute force
and against the mathematical structure of EBC (monotone submodular)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def brute_sqdist(a, b):
    n, m = a.shape[0], b.shape[0]
    out = np.zeros((n, m), np.float32)
    for i in range(n):
        for j in range(m):
            diff = a[i] - b[j]
            out[i, j] = float(np.dot(diff, diff))
    return out


def brute_ebc_value(v, s):
    """f(S) = L({e0}) - L(S ∪ {e0}), e0 = 0, straight from Def. 5."""
    n = v.shape[0]
    l_e0 = sum(float(np.dot(v[i], v[i])) for i in range(n)) / n
    acc = 0.0
    for i in range(n):
        best = float(np.dot(v[i], v[i]))  # distance to e0
        for srow in s:
            d = v[i] - srow
            best = min(best, float(np.dot(d, d)))
        acc += best
    return l_e0 - acc / n


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def test_pairwise_sqdist_matches_brute(rng):
    a = rng.normal(size=(17, 9)).astype(np.float32)
    b = rng.normal(size=(11, 9)).astype(np.float32)
    got = np.asarray(ref.pairwise_sqdist(jnp.array(a), jnp.array(b)))
    np.testing.assert_allclose(got, brute_sqdist(a, b), rtol=1e-4, atol=1e-4)


def test_ebc_value_matches_def5(rng):
    v = rng.normal(size=(25, 6)).astype(np.float32)
    idx = [3, 11, 19]
    s = v[idx]
    smask = np.ones(len(idx), np.float32)
    vmask = np.ones(25, np.float32)
    got = float(ref.ebc_value_ref(jnp.array(v), jnp.array(vmask),
                                  jnp.array(s), jnp.array(smask)))
    want = brute_ebc_value(v, s)
    assert abs(got - want) < 1e-4


def test_ebc_value_empty_set_is_zero(rng):
    v = rng.normal(size=(10, 4)).astype(np.float32)
    s = np.zeros((2, 4), np.float32)
    got = float(ref.ebc_value_ref(jnp.array(v), jnp.ones(10),
                                  jnp.array(s), jnp.zeros(2)))
    # masked-out set == empty set == f value 0... except e0 IS the zero
    # vector, so masked slots (+BIG) never win and f = 0
    assert abs(got) < 1e-5


def test_gains_equal_value_differences(rng):
    v = rng.normal(size=(30, 5)).astype(np.float32)
    vsq = (v * v).sum(1)
    vmask = np.ones(30, np.float32)
    base_idx = [4, 22]
    base = v[base_idx]
    d2 = brute_sqdist(v, base)
    mindist = np.minimum(d2.min(1), vsq)
    cands = v[[0, 9, 29]]
    g = np.asarray(ref.ebc_gains_ref(jnp.array(v), jnp.array(vsq),
                                     jnp.array(vmask), jnp.array(mindist),
                                     jnp.array(cands), jnp.ones(3)))
    f_base = brute_ebc_value(v, base)
    for ci, c in enumerate([0, 9, 29]):
        f_ext = brute_ebc_value(v, v[base_idx + [c]])
        assert abs(g[ci] - (f_ext - f_base)) < 1e-4


def test_monotone_and_submodular_sampled(rng):
    v = rng.normal(size=(15, 4)).astype(np.float32)
    vmask = np.ones(15, np.float32)

    def f(idx):
        if not idx:
            return 0.0
        s = v[list(idx)]
        return float(ref.ebc_value_ref(jnp.array(v), jnp.array(vmask),
                                       jnp.array(s), jnp.ones(len(idx))))

    for _ in range(10):
        a = set(rng.choice(15, size=2, replace=False).tolist())
        b = a | set(rng.choice(15, size=4, replace=False).tolist())
        e = int(rng.integers(15))
        if e in b:
            continue
        # monotone
        assert f(sorted(b)) >= f(sorted(a)) - 1e-5
        # submodular: gain at A >= gain at B
        ga = f(sorted(a | {e})) - f(sorted(a))
        gb = f(sorted(b | {e})) - f(sorted(b))
        assert ga >= gb - 1e-4


def test_update_consistent_with_eval_multi(rng):
    v = rng.normal(size=(20, 6)).astype(np.float32)
    vsq = (v * v).sum(1)
    vmask = np.ones(20, np.float32)
    mindist = vsq.copy()
    chosen = [2, 17]
    f_last = 0.0
    for c in chosen:
        mindist, f_last = ref.ebc_update_ref(
            jnp.array(v), jnp.array(vsq), jnp.array(vmask),
            jnp.array(mindist), jnp.array(v[c]))
        mindist = np.asarray(mindist)
    fs = ref.ebc_eval_multi_ref(
        jnp.array(v), jnp.array(vsq), jnp.array(vmask),
        jnp.array(v[chosen]), jnp.ones(2), 1)
    assert abs(float(f_last) - float(fs[0])) < 1e-5
