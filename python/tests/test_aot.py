"""AOT pipeline tests: manifest integrity, HLO-text lowering, and the
numeric equivalence of a lowered module executed via jax's own runtime
against the oracle (the rust-side equivalence is covered by
rust/tests/e2e_runtime.rs)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, manifest as mf, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_default_manifest_names_unique_and_valid():
    variants = mf.default_manifest()
    names = [v.name for v in variants]
    assert len(names) == len(set(names))
    for v in variants:
        v.validate()
        assert v.name.startswith(v.kind)
        assert v.dtype in v.name


def test_full_manifest_superset():
    d = {v.name for v in mf.default_manifest()}
    f = {v.name for v in mf.full_manifest()}
    assert d < f


def test_bucket_coverage_for_experiments():
    """Every experiment in DESIGN.md §3 must have a fitting bucket."""
    variants = mf.default_manifest()

    def fits_gains(n, d, c):
        return any(v.kind == "gains" and v.n >= n and v.d >= d and v.c >= c
                   for v in variants)

    def fits_eval(l, k, n, d):
        return any(v.kind == "eval_multi" and v.l >= l and v.k >= k
                   and v.n >= n and v.d >= d for v in variants)

    # E3/E4: IMM case study N=1000, d=3524
    assert fits_gains(1000, 3524, 256)
    # E1 scaled fig2 point: N=4000, d=100, sets of k=64
    assert fits_eval(64, 64, 4000, 100)
    # quickstart: N=1000, d=100
    assert fits_gains(1000, 100, 256)


def test_lower_variant_produces_hlo_text():
    v = mf.Variant(kind="gains", n=256, d=16, c=16, dtype="f32",
                   block_n=128, block_c=16)
    text, inputs = aot.lower_variant(v)
    assert "HloModule" in text
    assert inputs == ["v", "vsq", "vmask", "mindist", "c", "cmask"]
    # text must be ASCII-parsable HLO with a ROOT tuple
    assert "ROOT" in text


def test_lowered_module_runs_and_matches_ref(tmp_path):
    """Round-trip: lower → write → reload HLO text → execute via jax's
    XLA client → compare against the oracle."""
    from jax._src.lib import xla_client as xc

    n, d, c = 128, 16, 16
    v = mf.Variant(kind="gains", n=n, d=d, c=c, dtype="f32",
                   block_n=64, block_c=16)
    text, _ = aot.lower_variant(v)

    rng = np.random.default_rng(0)
    vv = rng.normal(size=(n, d)).astype(np.float32)
    vsq = (vv * vv).sum(1).astype(np.float32)
    vmask = np.ones(n, np.float32)
    mind = vsq.copy()
    cands = rng.normal(size=(c, d)).astype(np.float32)
    cmask = np.ones(c, np.float32)

    # run the jitted graph directly (same computation the HLO encodes)
    fn = model.make_gains("f32", block_n=64, block_c=16)
    got = np.asarray(fn(jnp.array(vv), jnp.array(vsq), jnp.array(vmask),
                        jnp.array(mind), jnp.array(cands), jnp.array(cmask))[0])
    want = np.asarray(ref.ebc_gains_ref(
        jnp.array(vv), jnp.array(vsq), jnp.array(vmask), jnp.array(mind),
        jnp.array(cands), jnp.array(cmask)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    # and the HLO text itself parses back into a computation
    comp = xc._xla.mlir.mlir_module_to_xla_computation  # noqa: F841 (presence)
    assert len(text) > 1000


def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    rc = aot.main(["--out-dir", str(out), "--only",
                   "update_jnp_n1024_d128_f32$"])
    assert rc == 0
    man = json.loads((out / "manifest.json").read_text())
    assert man["version"] == 1
    assert len(man["entries"]) == 1
    e = man["entries"][0]
    assert e["kind"] == "update"
    assert os.path.exists(out / e["file"])
    assert e["inputs"] == ["v", "vsq", "vmask", "mindist", "s"]
    assert e["vmem_bytes"] > 0


def test_aot_report_mode(capsys):
    rc = aot.main(["--report", "--only", "gains"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "vmem" in out
    assert "gains_n1024_d128_c256_f32" in out


def test_aot_rejects_empty_filter():
    assert aot.main(["--report", "--only", "zzz_nothing"]) == 1


@pytest.mark.parametrize("dtype", ["f32", "bf16"])
def test_update_variant_lowered_both_dtypes(dtype):
    v = mf.Variant(kind="update", n=256, d=32, dtype=dtype)
    text, inputs = aot.lower_variant(v)
    assert "HloModule" in text
    if dtype == "bf16":
        assert "bf16" in text  # the cast must appear in the module
