"""Independent Python mirror of the rust wire encoders
(``rust/src/shard/wire.rs``) + the frozen hex goldens from
``rust/tests/wire_golden.rs``.

The rust golden suite pins encode() output byte-for-byte; this mirror
re-derives every golden from the same struct values using nothing but
the layout documented in the wire module — stdlib only (struct + zlib),
no jax/numpy — so the frames can be cross-checked without a Rust
toolchain. If the two sides ever disagree, one of them mis-implements
the documented layout and the divergent byte is printed.

Run as a script (``python3 test_wire_goldens.py``) or under pytest.
``python3 test_wire_goldens.py --mint`` prints re-derived hex for all
goldens (how new goldens are minted for wire_golden.rs).
"""

import struct
import sys
import zlib

MAGIC = b"EBCW"
WIRE_VERSION = 2
WIRE_CONTROL_VERSION = 3
KIND = {"job": 1, "result": 2, "request": 3,
        "hello": 4, "heartbeat": 5, "goodbye": 6}
CONTROL_KINDS = {"hello", "heartbeat", "goodbye"}
PRECISION = {"f32": 0, "bf16": 1}
CPU_KERNEL = {"scalar": 0, "blocked": 1, "simd": 2}
KERNEL_IMPL = {"pallas": 0, "jnp": 1}
PART = {"bottom": 0, "plate": 1, "screw": 2}
STATE = {"calibration": 0, "production": 1, "downtimes": 2}
DATASET = {"inline": 0, "synthetic": 1, "imm": 2}


def u16(v):
    return struct.pack("<H", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def f32(v):
    return struct.pack("<f", v)


def f64(v):
    return struct.pack("<d", v)


def wstr(s):
    b = s.encode()
    return u32(len(b)) + b


def bf16_hi(v):
    """Upper 16 bits of bf16_round(v): round-to-nearest-even demotion."""
    (bits,) = struct.unpack("<I", struct.pack("<f", v))
    if (bits & 0x7F800000) == 0x7F800000 and (bits & 0x007FFFFF):
        return (bits >> 16) & 0xFFFF  # NaN passes through
    rounded = (bits + 0x7FFF + ((bits >> 16) & 1)) & 0xFFFFFFFF
    return (rounded >> 16) & 0xFFFF


def matrix(payload, rows, cols, data):
    p = u32(rows) + u32(cols)
    if payload == "f32":
        for v in data:
            p += f32(v)
    else:
        for v in data:
            p += u16(bf16_hi(v))
    return p


def seal(kind, payload):
    version = WIRE_CONTROL_VERSION if kind in CONTROL_KINDS else WIRE_VERSION
    frame = MAGIC + u16(version) + bytes([KIND[kind], 0]) + u32(len(payload))
    frame += payload
    return frame + u32(zlib.crc32(frame) & 0xFFFFFFFF)


def encode_job(shard, k, batch, optimizer, payload, precision, cpu_kernel,
               kernel, threads, plan, ground_ids, rows, cols, data):
    p = u32(shard) + u32(k) + u32(batch) + wstr(optimizer)
    p += bytes([PRECISION[payload], PRECISION[precision],
                CPU_KERNEL[cpu_kernel], KERNEL_IMPL[kernel]])
    p += (b"\x01" + u32(threads)) if threads is not None else (b"\x00" + u32(0))
    if plan is not None:
        p += b"\x01" + u32(plan["n"]) + u32(plan["d"]) + u32(plan["shards"])
        p += u32(plan["k"])
        p += bytes([PRECISION[plan["precision"]], KERNEL_IMPL[plan["kernel"]],
                    CPU_KERNEL[plan["cpu_kernel"]]])
        p += u32(plan["cores"]) + u32(plan["shard_workers"])
        p += u32(plan["oracle_threads"]) + u32(plan["merge_threads"])
    else:
        p += b"\x00"
    p += u32(len(ground_ids))
    for g in ground_ids:
        p += u64(g)
    p += matrix(payload, rows, cols, data)
    return seal("job", p)


def encode_result(shard, size, indices, f_trajectory, f_final, wall_seconds,
                  oracle_calls, oracle_work):
    p = u32(shard) + u32(size) + u32(len(indices))
    for i in indices:
        p += u64(i)
    p += u32(len(f_trajectory))
    for f in f_trajectory:
        p += f32(f)
    p += f32(f_final) + f64(wall_seconds) + u64(oracle_calls) + u64(oracle_work)
    return seal("result", p)


def encode_request(k, batch, optimizer, precision, cpu_kernel, threads, seed,
                   with_baseline, shard, dataset):
    p = u32(k) + u32(batch) + wstr(optimizer)
    p += bytes([PRECISION[precision], CPU_KERNEL[cpu_kernel]])
    p += u32(threads) + u64(seed) + bytes([1 if with_baseline else 0])
    if shard is not None:
        p += b"\x01" + u32(shard["partitions"]) + wstr(shard["partitioner"])
        p += u32(shard["per_shard_k"]) + u32(shard["threads"])
        p += wstr(shard["transport"]) + u32(shard["replicas"])
        p += bytes([1 if shard["plan"] else 0]) + u32(shard["cores"])
    else:
        p += b"\x00"
    p += bytes([DATASET[dataset["kind"]]])
    if dataset["kind"] == "inline":
        p += bytes([PRECISION[dataset["payload"]]])
        p += matrix(dataset["payload"], dataset["rows"], dataset["cols"],
                    dataset["data"])
    elif dataset["kind"] == "synthetic":
        p += u32(dataset["n"]) + u32(dataset["d"]) + u64(dataset["seed"])
    else:
        p += bytes([PART[dataset["part"]], STATE[dataset["state"]]])
        p += u32(dataset["samples"]) + u64(dataset["seed"])
    return seal("request", p)


def encode_hello(id_, capacity):
    return seal("hello", wstr(id_) + u32(capacity))


def encode_heartbeat(id_, seq):
    return seal("heartbeat", wstr(id_) + u64(seq))


def encode_goodbye(id_, drain, detail):
    return seal("goodbye", wstr(id_) + bytes([1 if drain else 0]) + wstr(detail))


# --------------------------------------------------------------- goldens
# Hex below is copied verbatim from rust/tests/wire_golden.rs; the struct
# values are copied from the same file's constructor functions.

GOLDENS = {
    "JOB_F32": (
        "45424357020001005c0000000100000002000000100000000600000067726565"
        "6479000001010102000000000300000003000000000000000500000000000000"
        "080000000000000003000000020000000000803f000000c00000003f00005040"
        "000040bf00008040961f66b1",
        lambda: encode_job(
            shard=1, k=2, batch=16, optimizer="greedy", payload="f32",
            precision="f32", cpu_kernel="blocked", kernel="jnp", threads=2,
            plan=None, ground_ids=[3, 5, 8], rows=3, cols=2,
            data=[1.0, -2.0, 0.5, 3.25, -0.75, 4.0]),
    ),
    "JOB_BF16_PLANNED": (
        "45424357020001006c0000000000000001000000080000000b0000006c617a79"
        "5f67726565647901010000000000000001400000000800000004000000030000"
        "0001010108000000040000000200000008000000020000000000000000000000"
        "02000000000000000200000002000000803f00c0203e40400c614240",
        lambda: encode_job(
            shard=0, k=1, batch=8, optimizer="lazy_greedy", payload="bf16",
            precision="bf16", cpu_kernel="scalar", kernel="pallas",
            threads=None,
            plan=dict(n=64, d=8, shards=4, k=3, precision="bf16",
                      kernel="jnp", cpu_kernel="blocked", cores=8,
                      shard_workers=4, oracle_threads=2, merge_threads=8),
            ground_ids=[0, 2], rows=2, cols=2, data=[1.0, -2.0, 0.15625, 3.0]),
    ),
    # PR 9: a job selecting the simd cpu kernel (code 2) — proves the
    # grown code set rides the unchanged v2 layout
    "JOB_SIMD": (
        None,  # minted by this mirror; frozen on the rust side
        lambda: encode_job(
            shard=3, k=2, batch=32, optimizer="greedy", payload="f32",
            precision="f32", cpu_kernel="simd", kernel="jnp", threads=4,
            plan=None, ground_ids=[1, 4], rows=2, cols=2,
            data=[0.5, -1.5, 2.0, -0.25]),
    ),
    "RESULT": (
        "454243570200020050000000020000000a000000030000000700000000000000"
        "03000000000000000900000000000000030000000000003f0000403f0000803f"
        "0000803f000000000000d03f2a00000000000000d20400000000000077354eae",
        lambda: encode_result(
            shard=2, size=10, indices=[7, 3, 9],
            f_trajectory=[0.5, 0.75, 1.0], f_final=1.0, wall_seconds=0.25,
            oracle_calls=42, oracle_work=1234),
    ),
    "REQUEST_SYNTHETIC": (
        "4542435702000300600000000500000000020000060000006772656564790001"
        "02000000bc0e000000000000010104000000080000006c6f63616c6974790000"
        "000000000000080000006c6f6f706261636b03000000010800000001e8030000"
        "200000002a00000000000000a904221e",
        lambda: encode_request(
            k=5, batch=512, optimizer="greedy", precision="f32",
            cpu_kernel="blocked", threads=2, seed=0xEBC, with_baseline=True,
            shard=dict(partitions=4, partitioner="locality", per_shard_k=0,
                       threads=0, transport="loopback", replicas=3, plan=True,
                       cores=8),
            dataset=dict(kind="synthetic", n=1000, d=32, seed=42)),
    ),
    "REQUEST_INLINE_BF16": (
        "45424357020003004100000002000000400000000f00000073696576655f7374"
        "7265616d696e6701000000000007000000000000000000000102000000030000"
        "00803f00c0203e4040003f80be4e1bb1c1",
        lambda: encode_request(
            k=2, batch=64, optimizer="sieve_streaming", precision="bf16",
            cpu_kernel="scalar", threads=0, seed=7, with_baseline=False,
            shard=None,
            dataset=dict(kind="inline", payload="bf16", rows=2, cols=3,
                         data=[1.0, -2.0, 0.15625, 3.0, 0.5, -0.25])),
    ),
    "HELLO": (
        "454243570300040011000000090000007265706c6963612d3704000000bf6849"
        "fb",
        lambda: encode_hello("replica-7", 4),
    ),
    "HEARTBEAT": (
        "454243570300050015000000090000007265706c6963612d372a000000000000"
        "004ee58850",
        lambda: encode_heartbeat("replica-7", 42),
    ),
    "GOODBYE": (
        "454243570300060024000000090000007265706c6963612d3701120000006d61"
        "696e74656e616e63652077696e646f77518c5fc3",
        lambda: encode_goodbye("replica-7", True, "maintenance window"),
    ),
}


def check_one(name, want_hex, encode):
    got = encode()
    crc_body, crc_stored = got[:-4], struct.unpack("<I", got[-4:])[0]
    assert zlib.crc32(crc_body) & 0xFFFFFFFF == crc_stored, f"{name}: bad CRC"
    if want_hex is None:
        return got
    want = bytes.fromhex(want_hex)
    if got != want:
        diff = next(i for i in range(min(len(got), len(want)) + 1)
                    if i >= len(got) or i >= len(want) or got[i] != want[i])
        raise AssertionError(
            f"{name}: mirror diverges from frozen golden at byte {diff}: "
            f"mirror={got.hex()} golden={want.hex()}")
    return got


def test_goldens_match_rust_frozen_frames():
    for name, (want_hex, encode) in GOLDENS.items():
        check_one(name, want_hex, encode)


def test_simd_code_sits_at_job_payload_offset_24():
    frame = GOLDENS["JOB_SIMD"][1]()
    header_len = 12
    # 12 fixed + 4-byte strlen + "greedy" (6) + payload + precision bytes
    assert frame[header_len + 24] == CPU_KERNEL["simd"] == 2


def main(argv):
    mint = "--mint" in argv
    for name, (want_hex, encode) in GOLDENS.items():
        frame = check_one(name, want_hex, encode)
        status = "minted" if want_hex is None else "matches frozen golden"
        print(f"{name}: {len(frame)} bytes, CRC ok, {status}")
        if mint or want_hex is None:
            h = frame.hex()
            for i in range(0, len(h), 64):
                print(f'    "{h[i:i + 64]}",')
    print("all frames verified")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
